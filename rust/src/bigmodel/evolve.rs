//! Streaming topology evolution for mapped models (DESIGN.md §14.5).
//!
//! [`evolve_epoch`] is the out-of-core twin of
//! [`EvolutionEngine::evolve_epoch`][crate::set::EvolutionEngine]: the
//! same fused importance + SET epoch, bit-exact against the in-RAM
//! engine and the sequential oracles, but with peak resident memory
//! O(plan) instead of O(nnz):
//!
//! * the engine's `part` scratch (a full copy of the value array for
//!   `select_nth_unstable`) is replaced by an **exact** two-pass
//!   bucket-histogram selection over the value *bit patterns*
//!   ([`streamed_prune_cuts`]) — O(1) scratch, zero RNG, same cuts to
//!   the last bit;
//! * the engine's `out_*` rebuild buffers are replaced by mapped windows
//!   into a **fresh staged segment**: survivors and regrowth merge
//!   straight to disk through [`rebuild_rows`] (the engine's own merge,
//!   `pub(crate)` for exactly this), row-chunked with
//!   `msync`+`MADV_DONTNEED` behind the cursor so only one chunk of the
//!   new generation is ever resident;
//! * the swap is the segment generation handover (seal → atomic rename
//!   over the live path → re-window), not a `Vec` swap — a crash at any
//!   point leaves either the old sealed generation or a refused `.tmp`.
//!
//! What stays in RAM is the *plan*: per-row survivor/regrowth counts and
//! prefix sums (O(n_rows)), the sampled gap ordinals and drawn weights
//! (O(to_grow)), and the per-output importance sums (O(n_cols)) — the
//! "plan in RAM, data on disk" split DESIGN.md §14.5 argues is the right
//! boundary.
//!
//! RNG layout is copied from the engine verbatim: one caller `u64` seeds
//! a root stream when SET is active (none on importance-only epochs),
//! layer `l` runs on `root.split(l)`, gap ordinals are drawn before the
//! regrown weights, weights in sorted (row, col) order. Parity is pinned
//! by `tests/outofcore_parity.rs` across thread counts and ISAs.

use crate::error::Result;
use crate::importance::{importance_threshold_from, ImportanceConfig};
use crate::set::engine::{rebuild_rows, EpochStats, KeepSpec};
use crate::set::{sample_gap_ordinals, EvolutionConfig};
use crate::util::Rng;

use super::model::BigModel;
use super::segment::Segment;

/// Output slots per rebuild chunk (~1 MiB of columns, ~1 MiB of values,
/// ~1 MiB of velocity resident at a time).
const CHUNK_SLOTS: usize = 1 << 18;

/// One fused evolution epoch over a mapped model — the out-of-core
/// equivalent of `EvolutionEngine::evolve_epoch` (same caller-RNG
/// consumption: one `u64` when `evo` is set, none otherwise; the final
/// classifier layer is importance-exempt). Layers whose epoch is a
/// provable no-op keep their current segment generation untouched.
pub fn evolve_epoch(
    model: &mut BigModel,
    evo: Option<&EvolutionConfig>,
    imp: Option<&ImportanceConfig>,
    rng: &mut Rng,
) -> Result<Vec<EpochStats>> {
    let n_layers = model.mlp.layers.len();
    if evo.is_none() && imp.is_none() {
        return Ok(vec![EpochStats::default(); n_layers]);
    }
    let root = match evo {
        Some(_) => Rng::new(rng.next_u64()),
        None => Rng::new(0),
    };
    let mut stats = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let imp_l = if l + 1 == n_layers { None } else { imp };
        let layer_rng = root.split(l as u64);
        stats.push(evolve_layer_streamed(model, l, evo, imp_l, layer_rng)?);
    }
    Ok(stats)
}

/// Plan one layer's epoch in RAM, then stream the rebuild into a fresh
/// segment generation and install it. Mirrors the engine's `plan_layer`
/// + `rebuild_and_swap` decision-for-decision.
fn evolve_layer_streamed(
    model: &mut BigModel,
    l: usize,
    evo: Option<&EvolutionConfig>,
    imp: Option<&ImportanceConfig>,
    mut rng: Rng,
) -> Result<EpochStats> {
    let layer = &model.mlp.layers[l];
    let w = &layer.weights;
    let (n_in, n_out) = (w.n_rows, w.n_cols);
    let nnz0 = w.nnz();

    // --- importance threshold (Eq. 4), engine's exact gating ---
    let mut imp_sums: Vec<f32> = Vec::new();
    let imp_thr: Option<f32> = match imp {
        Some(cfg) if nnz0 > cfg.min_connections => {
            imp_sums.resize(n_out, 0.0);
            for (&j, &v) in w.col_idx.iter().zip(w.values.iter()) {
                imp_sums[j as usize] += v.abs();
            }
            let mut active = Vec::new();
            importance_threshold_from(&imp_sums, nnz0, cfg, &mut active)
        }
        _ => None,
    };
    if evo.is_none() && imp_thr.is_none() {
        // provable no-op: current generation stays (the engine skips the
        // rebuild on this path too, and consumes no layer RNG either way)
        return Ok(EpochStats::default());
    }
    let imp_view: Option<(&[f32], f32)> = imp_thr.map(|thr| (imp_sums.as_slice(), thr));

    // --- SET prune cuts over the importance-surviving values: exact
    //     streamed selection instead of the engine's O(nnz) partition ---
    let (pos_cut, neg_cut, set_active) = match evo {
        Some(cfg) => {
            let (p, n) = streamed_prune_cuts(&w.col_idx, &w.values, imp_view, cfg.zeta);
            (p, n, true)
        }
        None => (0.0, 0.0, false),
    };
    let keep = KeepSpec {
        imp: imp_view,
        pos_cut,
        neg_cut,
        set_active,
    };

    // --- pass 1: per-row survivor counts + removal tallies ---
    let mut keep_counts = vec![0usize; n_in];
    let (mut total_kept, mut imp_pruned, mut set_pruned) = (0usize, 0usize, 0usize);
    for i in 0..n_in {
        let (s, e) = (w.row_ptr[i], w.row_ptr[i + 1]);
        let mut kept = 0usize;
        for k in s..e {
            if !keep.imp_ok(w.col_idx[k]) {
                imp_pruned += 1;
            } else if !keep.set_ok(w.values[k]) {
                set_pruned += 1;
            } else {
                kept += 1;
            }
        }
        keep_counts[i] = kept;
        total_kept += kept;
    }

    // --- regrowth plan: gap ordinals -> (row, col) -> weight draws,
    //     verbatim from the engine (identical RNG stream) ---
    let capacity = n_in * n_out - total_kept;
    let to_grow = if set_active {
        set_pruned.min(capacity)
    } else {
        0
    };
    let mut gap_prefix = vec![0usize; n_in + 1];
    for i in 0..n_in {
        gap_prefix[i + 1] = gap_prefix[i] + (n_out - keep_counts[i]);
    }
    debug_assert_eq!(gap_prefix[n_in], capacity);

    let mut ordinals = Vec::with_capacity(to_grow);
    let mut seen = std::collections::HashSet::with_capacity(to_grow);
    sample_gap_ordinals(&mut rng, capacity, to_grow, &mut ordinals, &mut seen);
    ordinals.sort_unstable();

    let mut grow_counts = vec![0usize; n_in];
    let mut grow_cols: Vec<u32> = Vec::with_capacity(to_grow);
    let mut grow_vals: Vec<f32> = Vec::with_capacity(to_grow);
    let mut oi = 0usize;
    for i in 0..n_in {
        if oi >= ordinals.len() {
            break;
        }
        let hi = gap_prefix[i + 1];
        if ordinals[oi] >= hi {
            continue;
        }
        let lo = gap_prefix[i];
        let (s, e) = (w.row_ptr[i], w.row_ptr[i + 1]);
        let row_start = grow_cols.len();
        // two-pointer gap selection over this row's (virtual) survivors:
        // the g-th empty column is g + #survivors c_t with c_t - t <= g
        let mut t = 0usize;
        let mut k = s;
        let mut next_surv: Option<usize> = None;
        while oi < ordinals.len() && ordinals[oi] < hi {
            let g = ordinals[oi] - lo;
            loop {
                if next_surv.is_none() {
                    while k < e {
                        if keep.keep(w.col_idx[k], w.values[k]) {
                            next_surv = Some(w.col_idx[k] as usize);
                            break;
                        }
                        k += 1;
                    }
                }
                match next_surv {
                    Some(c) if c - t <= g => {
                        t += 1;
                        k += 1;
                        next_surv = None;
                    }
                    _ => break,
                }
            }
            grow_cols.push((g + t) as u32);
            oi += 1;
        }
        grow_counts[i] = grow_cols.len() - row_start;
    }
    debug_assert_eq!(grow_cols.len(), to_grow);
    // weights drawn in sorted (row, col) order — the oracle's exact order
    if let Some(cfg) = evo {
        for _ in 0..to_grow {
            grow_vals.push(cfg.init.sample(&mut rng, n_in, n_out));
        }
    }

    let mut grow_ptr = vec![0usize; n_in + 1];
    let mut new_row_ptr = vec![0usize; n_in + 1];
    for i in 0..n_in {
        grow_ptr[i + 1] = grow_ptr[i] + grow_counts[i];
        new_row_ptr[i + 1] = new_row_ptr[i] + keep_counts[i] + grow_counts[i];
    }
    let new_nnz = new_row_ptr[n_in];
    debug_assert_eq!(new_nnz, total_kept + to_grow);

    // --- rebuild: merge straight into the next segment generation,
    //     one row chunk resident at a time ---
    let old_region = std::sync::Arc::clone(model.segment(l).region());
    // flush training-dirty pages so per-chunk drops behind the read
    // cursor cannot outrun writeback
    old_region.sync(0, old_region.len())?;
    let mut new_seg = Segment::create(model.segment(l).path(), n_in, n_out, new_nnz)?;
    {
        let mut rp = new_seg.row_ptr_buf()?;
        rp.as_mut_slice().copy_from_slice(&new_row_ptr);
    }
    {
        let mut col_win = new_seg.col_idx_buf()?;
        let mut val_win = new_seg.values_buf()?;
        let mut vel_win = new_seg.velocity_buf()?;
        let out_col = col_win.as_mut_slice();
        let out_val = val_win.as_mut_slice();
        let out_vel = vel_win.as_mut_slice();
        let old_vel = layer.velocity.as_slice();
        let lay = *new_seg.layout();
        let new_region = std::sync::Arc::clone(new_seg.region());
        let mut r0 = 0usize;
        while r0 < n_in {
            let mut r1 = r0 + 1;
            while r1 < n_in && new_row_ptr[r1 + 1] - new_row_ptr[r0] <= CHUNK_SLOTS {
                r1 += 1;
            }
            let (o0, o1) = (new_row_ptr[r0], new_row_ptr[r1]);
            rebuild_rows(
                w,
                old_vel,
                keep,
                &grow_cols,
                &grow_vals,
                &grow_ptr,
                &new_row_ptr,
                r0,
                r1,
                &mut out_col[o0..o1],
                &mut out_val[o0..o1],
                &mut out_vel[o0..o1],
            );
            // retire the chunk: new-generation slots flushed and dropped,
            // old-generation rows (already synced above) dropped
            for base in [lay.col_idx_off, lay.values_off, lay.velocity_off] {
                let off = base as usize + o0 * 4;
                let len = (o1 - o0) * 4;
                new_region.sync(off, len)?;
                new_region.advise_dontneed(off, len);
            }
            let (s0, s1) = (w.row_ptr[r0], w.row_ptr[r1]);
            let old_lay = *model.segment(l).layout();
            for base in [old_lay.col_idx_off, old_lay.values_off, old_lay.velocity_off] {
                old_region.advise_dontneed(base as usize + s0 * 4, (s1 - s0) * 4);
            }
            r0 = r1;
        }
    }
    new_seg.write_bias(&layer.bias, &layer.bias_velocity)?;
    new_seg.seal()?;
    model.install_segment(l, new_seg)?;
    Ok(EpochStats {
        importance_pruned: imp_pruned,
        pruned: set_pruned,
        regrown: to_grow,
    })
}

/// Exact SET prune cuts with O(1) scratch: the streamed replacement for
/// `partition_signs` + `thresholds_from_partition`.
///
/// Why this is bit-exact (not approximate): for finite IEEE-754 floats of
/// one sign, numeric order and unsigned bit-pattern order coincide —
/// ascending for positives, and for negatives *descending numeric*
/// (closest to zero first, the order the SET cut ranks in) is ascending
/// bit order. So both cuts are "the value whose u32 pattern has rank
/// `k-1` in ascending bit order within its sign class", recoverable by
/// histogram prefix sums: a coarse pass over the high 16 pattern bits
/// locates the winning bucket, a fine pass over the low 16 bits inside
/// that bucket pins the exact pattern. Ties are harmless — equal floats
/// share one pattern, and `select_nth_unstable_by` returns that value.
/// Zeros are excluded (`v > 0.0` / `v < 0.0`), matching the partition.
pub(crate) fn streamed_prune_cuts(
    col_idx: &[u32],
    values: &[f32],
    imp: Option<(&[f32], f32)>,
    zeta: f64,
) -> (f32, f32) {
    let imp_ok = |j: u32| match imp {
        Some((sums, thr)) => sums[j as usize] >= thr,
        None => true,
    };
    // coarse: one histogram over the high 16 pattern bits; positives land
    // in [0, 0x8000), negatives in [0x8000, 0x10000), each ascending in
    // its class's selection order
    let mut coarse = vec![0u64; 1 << 16];
    for (&j, &v) in col_idx.iter().zip(values.iter()) {
        if (v > 0.0 || v < 0.0) && imp_ok(j) {
            coarse[(v.to_bits() >> 16) as usize] += 1;
        }
    }
    let npos: u64 = coarse[..1 << 15].iter().sum();
    let nneg: u64 = coarse[1 << 15..].iter().sum();
    let kp = (npos as f64 * zeta).floor() as u64;
    let kn = (nneg as f64 * zeta).floor() as u64;
    let pos_bucket = (kp > 0).then(|| locate_bucket(&coarse[..1 << 15], kp - 1));
    let neg_bucket =
        (kn > 0).then(|| locate_bucket(&coarse[1 << 15..], kn - 1)).map(|(b, r)| (b + (1 << 15), r));
    drop(coarse);
    // fine: low 16 bits inside each winning bucket, both classes in one
    // second pass
    let mut fine_pos = vec![0u64; 1 << 16];
    let mut fine_neg = vec![0u64; 1 << 16];
    if pos_bucket.is_some() || neg_bucket.is_some() {
        for (&j, &v) in col_idx.iter().zip(values.iter()) {
            if (v > 0.0 || v < 0.0) && imp_ok(j) {
                let bits = v.to_bits();
                let hi = (bits >> 16) as usize;
                if Some(hi) == pos_bucket.map(|(b, _)| b) {
                    fine_pos[(bits & 0xFFFF) as usize] += 1;
                } else if Some(hi) == neg_bucket.map(|(b, _)| b) {
                    fine_neg[(bits & 0xFFFF) as usize] += 1;
                }
            }
        }
    }
    let cut = |bucket: Option<(usize, u64)>, fine: &[u64]| -> f32 {
        match bucket {
            None => 0.0,
            Some((b, rank)) => {
                let (lo, _) = locate_bucket(fine, rank);
                f32::from_bits(((b as u32) << 16) | lo as u32)
            }
        }
    };
    (cut(pos_bucket, &fine_pos), cut(neg_bucket, &fine_neg))
}

/// Index of the histogram bucket containing ascending rank `rank`, plus
/// the remaining rank *within* that bucket.
fn locate_bucket(hist: &[u64], rank: u64) -> (usize, u64) {
    let mut before = 0u64;
    for (b, &c) in hist.iter().enumerate() {
        if before + c > rank {
            return (b, rank - before);
        }
        before += c;
    }
    unreachable!("rank {rank} beyond histogram total {before}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::set::prune_thresholds;

    /// Filter `values` the way the engine's partition does, then ask the
    /// in-RAM oracle for its cuts.
    fn oracle_cuts(
        col_idx: &[u32],
        values: &[f32],
        imp: Option<(&[f32], f32)>,
        zeta: f64,
    ) -> (f32, f32) {
        let filtered: Vec<f32> = col_idx
            .iter()
            .zip(values.iter())
            .filter(|(&j, _)| match imp {
                Some((sums, thr)) => sums[j as usize] >= thr,
                None => true,
            })
            .map(|(_, &v)| v)
            .collect();
        prune_thresholds(&filtered, zeta)
    }

    fn check(col_idx: &[u32], values: &[f32], imp: Option<(&[f32], f32)>, zeta: f64, label: &str) {
        let want = oracle_cuts(col_idx, values, imp, zeta);
        let got = streamed_prune_cuts(col_idx, values, imp, zeta);
        assert_eq!(
            want.0.to_bits(),
            got.0.to_bits(),
            "{label}: positive cut (want {}, got {})",
            want.0,
            got.0
        );
        assert_eq!(
            want.1.to_bits(),
            got.1.to_bits(),
            "{label}: negative cut (want {}, got {})",
            want.1,
            got.1
        );
    }

    #[test]
    fn streamed_cuts_match_the_select_nth_oracle() {
        let mut rng = Rng::new(42);
        for trial in 0..50 {
            let n = 1 + rng.below_usize(400);
            let values: Vec<f32> = (0..n)
                .map(|_| match rng.below_usize(10) {
                    0 => 0.0,
                    1 => values_tie(trial),
                    _ => rng.normal(),
                })
                .collect();
            let col_idx: Vec<u32> = (0..n).map(|_| rng.below_usize(7) as u32).collect();
            for zeta in [0.0, 0.1, 0.3, 0.5, 0.99, 1.0] {
                check(&col_idx, &values, None, zeta, &format!("trial {trial} ζ={zeta}"));
            }
        }
    }

    /// A repeated value so the selection regularly lands on ties.
    fn values_tie(trial: usize) -> f32 {
        if trial % 2 == 0 {
            0.25
        } else {
            -0.25
        }
    }

    #[test]
    fn streamed_cuts_honor_the_importance_filter() {
        let mut rng = Rng::new(7);
        let n = 300;
        let n_out = 9usize;
        let values: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let col_idx: Vec<u32> = (0..n).map(|_| rng.below_usize(n_out) as u32).collect();
        let sums: Vec<f32> = (0..n_out).map(|j| j as f32).collect();
        for thr in [0.0f32, 3.0, 8.0, 100.0] {
            check(
                &col_idx,
                &values,
                Some((&sums, thr)),
                0.3,
                &format!("thr={thr}"),
            );
        }
    }

    #[test]
    fn streamed_cuts_edge_cases() {
        // empty, all-zero, single-sign, single-element
        check(&[], &[], None, 0.3, "empty");
        check(&[0, 0, 0], &[0.0, 0.0, 0.0], None, 0.5, "all zeros");
        check(&[0, 1, 2], &[1.0, 2.0, 3.0], None, 0.5, "all positive");
        check(&[0, 1, 2], &[-1.0, -2.0, -3.0], None, 0.5, "all negative");
        check(&[0], &[0.5], None, 1.0, "single ζ=1");
        // denormals and extremes keep the bit-order argument honest
        check(
            &[0, 1, 2, 3, 4, 5],
            &[f32::MIN_POSITIVE / 2.0, 1e-30, -1e-30, f32::MAX, f32::MIN, -f32::MIN_POSITIVE],
            None,
            0.5,
            "denormals/extremes",
        );
    }

    use crate::util::Rng;

    #[cfg(target_os = "linux")]
    #[test]
    fn mapped_epoch_matches_the_inram_engine() {
        use crate::model::SparseMlp;
        use crate::nn::Activation;
        use crate::set::EvolutionEngine;
        use crate::sparse::WeightInit;

        let dir = std::env::temp_dir()
            .join(format!("tsnn_bigmodel_evolve_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sizes = [31usize, 44, 6];
        let act = Activation::Relu;
        let init = WeightInit::Normal(0.5);
        let mut ram = SparseMlp::new(&sizes, 6.0, act, &init, &mut Rng::new(21)).unwrap();
        let mut big = BigModel::create(&dir, &sizes, 6.0, act, &init, &mut Rng::new(21)).unwrap();
        // non-trivial optimizer state so velocity remapping is observable
        for (lr, lb) in ram.layers.iter_mut().zip(big.mlp.layers.iter_mut()) {
            for (k, (vr, vb)) in lr
                .velocity
                .iter_mut()
                .zip(lb.velocity.as_mut_slice().iter_mut())
                .enumerate()
            {
                *vr = 0.25 * (k + 1) as f32;
                *vb = 0.25 * (k + 1) as f32;
            }
        }
        let evo = EvolutionConfig::default();
        let imp = ImportanceConfig {
            start_epoch: 0,
            period: 1,
            percentile: 20.0,
            min_connections: 0,
        };
        let mut engine = EvolutionEngine::new();
        for round in 0..3 {
            let mut rng_a = Rng::new(100 + round);
            let mut rng_b = Rng::new(100 + round);
            let want = engine
                .evolve_epoch(&mut ram, Some(&evo), Some(&imp), &mut rng_a, 1)
                .unwrap();
            let got = evolve_epoch(&mut big, Some(&evo), Some(&imp), &mut rng_b).unwrap();
            assert_eq!(want, got, "round {round}: stats");
            for (l, (a, b)) in ram.layers.iter().zip(big.mlp.layers.iter()).enumerate() {
                assert_eq!(a.weights, b.weights, "round {round} layer {l}: weights");
                assert_eq!(
                    a.velocity.as_slice(),
                    b.velocity.as_slice(),
                    "round {round} layer {l}: velocity"
                );
            }
        }
        // the new generations survive a close + reopen
        big.persist().unwrap();
        drop(big);
        let back = BigModel::open(&dir).unwrap();
        for (l, (a, b)) in ram.layers.iter().zip(back.mlp.layers.iter()).enumerate() {
            assert_eq!(a.weights, b.weights, "reopen layer {l}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
