//! [`BigModel`]: a [`SparseMlp`] whose layer arrays live in mapped
//! `TSNS` segment files (DESIGN.md §14.3) — model size bounded by disk,
//! resident memory by what the kernels touch (plus whatever the
//! [`crate::bigmodel::residency`] advisor lets linger).
//!
//! The wrapped `mlp` field is a *real* [`SparseMlp`] — same struct, same
//! kernels, same `Workspace` — whose `row_ptr`/`col_idx`/`values`/
//! `velocity` buffers are [`Buf::Mapped`] windows into one segment per
//! layer. Everything that takes `&SparseMlp`/`&mut SparseMlp`
//! (forward, train_step, evaluate, checkpoint::save) works unchanged;
//! only *structural* rebuilds must go through [`crate::bigmodel::evolve`]
//! (the in-RAM engine's swap would silently materialise the layer).
//!
//! Initialisation parity: [`BigModel::create`] draws its Erdős–Rényi
//! topology through the same [`er_sample_row`] per-row sequence as
//! [`SparseMlp::new`] — row degrees, sorted columns, then one weight
//! draw per link — so a `BigModel` and a `SparseMlp` built from equal
//! RNG states are bit-identical (pinned by `tests/outofcore_parity.rs`).
//! The draw pass streams each row's slots to spill files and the final
//! segment is assembled by a chunked disk-to-disk copy, so peak resident
//! memory during creation is O(n_rows + chunk), never O(nnz).

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Result, TsnnError};
use crate::model::checkpoint::act_name;
use crate::model::{SparseLayer, SparseMlp};
use crate::nn::Activation;
use crate::sparse::{epsilon_density, er_sample_row, CsrMatrix, MapRegion, WeightInit};
use crate::util::json::{obj, parse, Json};
use crate::util::Rng;

use super::segment::{Segment, STREAM_CHUNK};

/// Manifest file name inside a model directory.
pub const MANIFEST: &str = "model.tsnm";
const MANIFEST_MAGIC: &str = "TSNM";
const MANIFEST_VERSION: f64 = 1.0;

/// Segment file of layer `l` inside `dir`.
pub fn layer_path(dir: &Path, l: usize) -> PathBuf {
    dir.join(format!("layer_{l}.tsns"))
}

/// An out-of-core sparse MLP: one mapped segment per layer plus a tiny
/// JSON manifest (sizes + activations) tying the directory together.
#[derive(Debug)]
pub struct BigModel {
    /// The trainable model; its layer buffers are mapped windows into
    /// `segments`. Use it directly with the normal kernels/Workspace.
    pub mlp: SparseMlp,
    segments: Vec<Segment>,
    dir: PathBuf,
}

impl BigModel {
    /// Build a fresh model under `dir` with the exact RNG consumption of
    /// [`SparseMlp::new`], then open it mapped. Hidden layers get
    /// `activation`, the output layer is linear, biases start at zero.
    pub fn create(
        dir: &Path,
        sizes: &[usize],
        epsilon: f64,
        activation: Activation,
        init: &WeightInit,
        rng: &mut Rng,
    ) -> Result<BigModel> {
        if sizes.len() < 2 {
            return Err(TsnnError::Config("need at least input+output sizes".into()));
        }
        std::fs::create_dir_all(dir)?;
        let n_layers = sizes.len() - 1;
        let mut acts = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let act = if l + 1 == n_layers {
                Activation::Linear
            } else {
                activation
            };
            acts.push(act);
            build_layer_segment(&layer_path(dir, l), sizes[l], sizes[l + 1], epsilon, init, rng)?;
        }
        write_manifest(dir, sizes, &acts)?;
        BigModel::open(dir)
    }

    /// Open an existing model directory: manifest parsed, every segment
    /// CRC-verified and mapped, bias state read into RAM.
    pub fn open(dir: &Path) -> Result<BigModel> {
        let (sizes, acts) = read_manifest(dir)?;
        let n_layers = sizes.len() - 1;
        let mut segments = Vec::with_capacity(n_layers);
        let mut layers = Vec::with_capacity(n_layers);
        for (l, &act) in acts.iter().enumerate().take(n_layers) {
            let seg = Segment::open(&layer_path(dir, l))?;
            let lay = seg.layout();
            if lay.n_rows != sizes[l] as u64 || lay.n_cols != sizes[l + 1] as u64 {
                return Err(TsnnError::Storage(format!(
                    "layer {l} segment is {}x{}, manifest says {}x{}",
                    lay.n_rows,
                    lay.n_cols,
                    sizes[l],
                    sizes[l + 1]
                )));
            }
            let (bias, bias_velocity) = seg.read_bias()?;
            layers.push(SparseLayer {
                weights: CsrMatrix {
                    n_rows: sizes[l],
                    n_cols: sizes[l + 1],
                    row_ptr: seg.row_ptr_buf()?,
                    col_idx: seg.col_idx_buf()?,
                    values: seg.values_buf()?,
                },
                bias,
                velocity: seg.velocity_buf()?,
                bias_velocity,
                activation: act,
                srelu: None,
            });
            segments.push(seg);
        }
        Ok(BigModel {
            mlp: SparseMlp { sizes, layers },
            segments,
            dir: dir.to_path_buf(),
        })
    }

    /// Model directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The segment behind layer `l`.
    pub fn segment(&self, l: usize) -> &Segment {
        &self.segments[l]
    }

    /// Per-layer mapped regions, in layer order (for the residency
    /// advisor).
    pub fn regions(&self) -> Vec<Arc<MapRegion>> {
        self.segments.iter().map(|s| Arc::clone(s.region())).collect()
    }

    /// Total bytes of all segment files — the number the extreme-scale
    /// bench compares against the RAM budget.
    pub fn total_segment_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.file_len()).sum()
    }

    /// Flush the RAM bias state into every segment and re-seal them
    /// (msync + fresh CRC trailers), making the on-disk model
    /// self-consistent at this instant. Weight/velocity mutations since
    /// the last `persist` were already reaching the page cache; this
    /// pins them to the file and restores CRC validity.
    pub fn persist(&mut self) -> Result<()> {
        for (seg, layer) in self.segments.iter_mut().zip(self.mlp.layers.iter()) {
            seg.write_bias(&layer.bias, &layer.bias_velocity)?;
            seg.seal()?;
        }
        Ok(())
    }

    /// Install the next generation of layer `l` (a sealed rebuild from
    /// [`crate::bigmodel::evolve`], already renamed over the live path)
    /// and re-window the layer's buffers onto it. Bias state stays the
    /// RAM copy the layer already holds.
    pub fn install_segment(&mut self, l: usize, new_seg: Segment) -> Result<()> {
        let layer = &mut self.mlp.layers[l];
        layer.weights.row_ptr = new_seg.row_ptr_buf()?;
        layer.weights.col_idx = new_seg.col_idx_buf()?;
        layer.weights.values = new_seg.values_buf()?;
        layer.velocity = new_seg.velocity_buf()?;
        self.segments[l].replace_with(new_seg);
        Ok(())
    }

    /// Save a standard `TSNN` checkpoint of the current weights (reads
    /// stream through the mapping; the file is byte-identical to one
    /// saved from an in-RAM model in the same state).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        crate::model::checkpoint::save(&self.mlp, path)
    }
}

/// Stream one layer's Erdős–Rényi draw into a sealed segment: rows are
/// drawn with [`er_sample_row`] (the exact [`SparseMlp::new`] sequence),
/// spilled to temporary files, and copied chunk-wise into the mapped
/// sections once the total nnz is known.
fn build_layer_segment(
    path: &Path,
    n_in: usize,
    n_out: usize,
    epsilon: f64,
    init: &WeightInit,
    rng: &mut Rng,
) -> Result<()> {
    let density = epsilon_density(epsilon, n_in, n_out);
    let spill_cols = path.with_extension("cols.spill");
    let spill_vals = path.with_extension("vals.spill");
    let mut row_ptr: Vec<usize> = Vec::with_capacity(n_in + 1);
    row_ptr.push(0);
    {
        let mut wc = BufWriter::new(File::create(&spill_cols)?);
        let mut wv = BufWriter::new(File::create(&spill_vals)?);
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        for _ in 0..n_in {
            er_sample_row(rng, n_in, n_out, density, init, &mut cols, &mut vals);
            for &c in &cols {
                wc.write_all(&c.to_le_bytes())?;
            }
            for &v in &vals {
                wv.write_all(&v.to_le_bytes())?;
            }
            row_ptr.push(row_ptr[row_ptr.len() - 1] + cols.len());
        }
        wc.flush()?;
        wv.flush()?;
    }
    let nnz = row_ptr[n_in];
    let mut seg = Segment::create(path, n_in, n_out, nnz)?;
    {
        let mut rp = seg.row_ptr_buf()?;
        rp.as_mut_slice().copy_from_slice(&row_ptr);
    }
    copy_spill_u32(&spill_cols, &mut seg)?;
    copy_spill_f32(&spill_vals, &mut seg)?;
    // velocity / bias / bias_velocity sections are already zero (the
    // file was sized with set_len), matching the in-RAM initialiser
    seg.seal()?;
    std::fs::remove_file(&spill_cols)?;
    std::fs::remove_file(&spill_vals)?;
    Ok(())
}

fn copy_spill_u32(spill: &Path, seg: &mut Segment) -> Result<()> {
    let mut window = seg.col_idx_buf()?;
    let out = window.as_mut_slice();
    let mut f = File::open(spill)?;
    let mut chunk = vec![0u8; STREAM_CHUNK];
    let mut at = 0usize;
    loop {
        let n = read_full(&mut f, &mut chunk)?;
        if n == 0 {
            break;
        }
        for (slot, b) in out[at..at + n / 4].iter_mut().zip(chunk[..n].chunks_exact(4)) {
            *slot = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        at += n / 4;
        let region = seg.region();
        let byte_base = crate::sparse::storage::checked_usize(seg.layout().col_idx_off, "col_idx offset")?;
        region.sync(byte_base + (at * 4).saturating_sub(n), n)?;
        region.advise_dontneed(byte_base + (at * 4).saturating_sub(n), n);
    }
    if at != out.len() {
        return Err(TsnnError::Storage(format!(
            "col spill holds {at} entries, segment expects {}",
            out.len()
        )));
    }
    Ok(())
}

fn copy_spill_f32(spill: &Path, seg: &mut Segment) -> Result<()> {
    let mut window = seg.values_buf()?;
    let out = window.as_mut_slice();
    let mut f = File::open(spill)?;
    let mut chunk = vec![0u8; STREAM_CHUNK];
    let mut at = 0usize;
    loop {
        let n = read_full(&mut f, &mut chunk)?;
        if n == 0 {
            break;
        }
        for (slot, b) in out[at..at + n / 4].iter_mut().zip(chunk[..n].chunks_exact(4)) {
            *slot = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        at += n / 4;
        let region = seg.region();
        let byte_base = crate::sparse::storage::checked_usize(seg.layout().values_off, "values offset")?;
        region.sync(byte_base + (at * 4).saturating_sub(n), n)?;
        region.advise_dontneed(byte_base + (at * 4).saturating_sub(n), n);
    }
    if at != out.len() {
        return Err(TsnnError::Storage(format!(
            "value spill holds {at} entries, segment expects {}",
            out.len()
        )));
    }
    Ok(())
}

/// `Read::read` until `buf` is full or EOF; returns bytes read.
fn read_full(f: &mut File, buf: &mut [u8]) -> Result<usize> {
    let mut n = 0usize;
    while n < buf.len() {
        let got = f.read(&mut buf[n..])?;
        if got == 0 {
            break;
        }
        n += got;
    }
    Ok(n)
}

fn write_manifest(dir: &Path, sizes: &[usize], acts: &[Activation]) -> Result<()> {
    let doc = obj(vec![
        ("magic", Json::Str(MANIFEST_MAGIC.into())),
        ("version", Json::Num(MANIFEST_VERSION)),
        (
            "sizes",
            Json::Arr(sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        (
            "activations",
            Json::Arr(acts.iter().map(|a| Json::Str(act_name(a))).collect()),
        ),
    ]);
    let tmp = dir.join(format!("{MANIFEST}.tmp"));
    let final_path = dir.join(MANIFEST);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(doc.dump().as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &final_path)?;
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

fn read_manifest(dir: &Path) -> Result<(Vec<usize>, Vec<Activation>)> {
    let path = dir.join(MANIFEST);
    let text = std::fs::read_to_string(&path)?;
    let doc = parse(&text)
        .map_err(|e| TsnnError::Storage(format!("{}: manifest parse: {e}", path.display())))?;
    if doc.get("magic").and_then(Json::as_str) != Some(MANIFEST_MAGIC) {
        return Err(TsnnError::Storage(format!(
            "{}: not a TSNM model manifest",
            path.display()
        )));
    }
    let sizes: Vec<usize> = doc
        .get("sizes")
        .and_then(Json::as_arr)
        .ok_or_else(|| TsnnError::Storage("manifest missing sizes".into()))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    let acts: Vec<Activation> = doc
        .get("activations")
        .and_then(Json::as_arr)
        .ok_or_else(|| TsnnError::Storage("manifest missing activations".into()))?
        .iter()
        .filter_map(|v| v.as_str().and_then(Activation::parse))
        .collect();
    if sizes.len() < 2 || acts.len() != sizes.len() - 1 {
        return Err(TsnnError::Storage(format!(
            "manifest shape mismatch: {} sizes, {} activations",
            sizes.len(),
            acts.len()
        )));
    }
    Ok((sizes, acts))
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    fn test_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tsnn_bigmodel_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn create_is_bit_identical_to_sparse_mlp_new() {
        let dir = test_dir("init_parity");
        let sizes = [17usize, 29, 5];
        let act = Activation::AllRelu { alpha: 0.6 };
        let init = WeightInit::HeUniform;
        let ram = SparseMlp::new(&sizes, 3.0, act, &init, &mut Rng::new(99)).unwrap();
        let big = BigModel::create(&dir, &sizes, 3.0, act, &init, &mut Rng::new(99)).unwrap();
        assert_eq!(ram.sizes, big.mlp.sizes);
        for (l, (a, b)) in ram.layers.iter().zip(big.mlp.layers.iter()).enumerate() {
            assert!(b.weights.values.is_mapped(), "layer {l} must be mapped");
            assert_eq!(a.weights, b.weights, "layer {l} weights");
            assert_eq!(a.bias, b.bias, "layer {l} bias");
            assert_eq!(a.velocity, b.velocity, "layer {l} velocity");
            assert_eq!(a.activation, b.activation, "layer {l} activation");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn persist_then_reopen_preserves_all_state() {
        let dir = test_dir("reopen");
        let sizes = [9usize, 12, 4];
        let mut big = BigModel::create(
            &dir,
            &sizes,
            2.0,
            Activation::Relu,
            &WeightInit::Normal(0.4),
            &mut Rng::new(5),
        )
        .unwrap();
        // mutate every piece of state through the mapped windows
        for layer in big.mlp.layers.iter_mut() {
            for v in layer.weights.values.as_mut_slice() {
                *v += 0.5;
            }
            for v in layer.velocity.as_mut_slice() {
                *v = 0.125;
            }
            for b in layer.bias.iter_mut() {
                *b = 1.5;
            }
        }
        let want: Vec<_> = big
            .mlp
            .layers
            .iter()
            .map(|l| (l.weights.clone(), l.velocity.to_vec(), l.bias.clone()))
            .collect();
        big.persist().unwrap();
        drop(big);
        let back = BigModel::open(&dir).unwrap();
        for (l, (layer, (w, v, b))) in back.mlp.layers.iter().zip(want.iter()).enumerate() {
            assert_eq!(&layer.weights, w, "layer {l} weights");
            assert_eq!(layer.velocity.as_slice(), v.as_slice(), "layer {l} velocity");
            assert_eq!(&layer.bias, b, "layer {l} bias");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_from_mapped_matches_checkpoint_from_ram() {
        let dir = test_dir("ckpt");
        let sizes = [11usize, 16, 3];
        let act = Activation::AllRelu { alpha: 0.75 };
        let init = WeightInit::HeUniform;
        let ram = SparseMlp::new(&sizes, 4.0, act, &init, &mut Rng::new(31)).unwrap();
        let big = BigModel::create(&dir, &sizes, 4.0, act, &init, &mut Rng::new(31)).unwrap();
        let p_ram = dir.join("ram.tsnn");
        let p_map = dir.join("map.tsnn");
        crate::model::checkpoint::save(&ram, &p_ram).unwrap();
        big.save_checkpoint(&p_map).unwrap();
        assert_eq!(
            std::fs::read(&p_ram).unwrap(),
            std::fs::read(&p_map).unwrap(),
            "mapped and RAM checkpoints must be byte-identical"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
