//! Out-of-core training driver (DESIGN.md §14.7).
//!
//! [`train_big`] runs the exact epoch loop of the in-RAM sequential
//! driver (`train::train_model`) over a mapped [`BigModel`]: same
//! batcher shuffles, same `train_step` calls, same fused
//! evolution/importance dispatch, same evaluation cadence — and,
//! crucially, the **same RNG consumption at every point**, so a mapped
//! run and an in-RAM run from equal seeds produce bit-identical models
//! (`tests/outofcore_parity.rs` pins final checkpoints byte-for-byte).
//!
//! What it deliberately does NOT do is clone the model: `TrainReport`
//! carries a `SparseMlp` by value, which for a beyond-RAM model is
//! exactly the allocation this subsystem exists to avoid. The
//! [`BigTrainReport`] carries logs and accounting only — the trained
//! weights live in the (persisted) segment files.
//!
//! Differences from the in-RAM loop, all RNG-neutral:
//! * topology evolution routes to the streaming
//!   [`evolve_epoch`](super::evolve::evolve_epoch) (segment-generation
//!   rebuilds) instead of the in-place engine — bit-equal by
//!   construction, and importance-only epochs use the same streamed path
//!   (which consumes no caller randomness, like `prune_model`);
//! * an optional [`SegmentResidency`] advisor rides in the workspace and
//!   is re-pointed at the new segment generations after each evolution
//!   epoch;
//! * `persist_every` reseals the segments periodically (and always once
//!   at the end — training dirties mapped values in place, so the final
//!   reseal is what restores CRC validity for a later
//!   [`BigModel::open`]).

use std::sync::Arc;

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::model::Batcher;
use crate::nn::Dropout;
use crate::train::EpochLog;
use crate::util::{Rng, Timer};

use super::evolve::evolve_epoch;
use super::model::BigModel;
use super::residency::{vm_hwm_bytes, SegmentResidency};

/// Knobs specific to out-of-core runs (everything else comes from the
/// shared [`TrainConfig`]).
#[derive(Debug, Clone, Default)]
pub struct BigTrainOptions {
    /// Install a [`SegmentResidency`] advisor with this soft RSS budget
    /// (bytes). `None` trains without in-process eviction pressure.
    pub soft_budget_bytes: Option<u64>,
    /// Advisor `/proc` polling cadence (hook calls per check; 0 = every
    /// hook).
    pub residency_check_every: usize,
    /// Reseal all segments every N completed epochs (0 = only at the
    /// end).
    pub persist_every: usize,
    /// Progress lines via `log`.
    pub verbose: bool,
}

/// Outcome of an out-of-core run. No model clone — the trained weights
/// are the sealed segment files in the model directory.
#[derive(Debug)]
pub struct BigTrainReport {
    /// Per-epoch records (same shape as the in-RAM report's).
    pub epochs: Vec<EpochLog>,
    /// Stored weights at the start of training.
    pub start_weights: usize,
    /// Stored weights at the end.
    pub end_weights: usize,
    /// Best test accuracy observed.
    pub best_test_accuracy: f32,
    /// Final test accuracy.
    pub final_test_accuracy: f32,
    /// `VmHWM` after training — the number the extreme-scale bench
    /// asserts against the RAM budget (`None` off-Linux).
    pub peak_rss_bytes: Option<u64>,
    /// Residency sync+drop events (0 without an advisor).
    pub trim_events: usize,
}

/// Train a mapped model in place. RNG consumption is identical to
/// `train::train_model` with the same config, which is what makes the
/// mapped-vs-RAM parity suite possible.
pub fn train_big(
    cfg: &TrainConfig,
    data: &Dataset,
    model: &mut BigModel,
    rng: &mut Rng,
    opts: &BigTrainOptions,
) -> Result<BigTrainReport> {
    let start_weights = model.mlp.weight_count();
    let mut ws = model.mlp.alloc_workspace(cfg.batch);
    ws.kernel_threads = cfg.kernel_threads;
    ws.ensure_pool();
    let advisor = opts.soft_budget_bytes.map(|budget| {
        Arc::new(SegmentResidency::new(
            model.regions(),
            budget,
            opts.residency_check_every,
        ))
    });
    if let Some(adv) = &advisor {
        ws.residency = Some(Arc::clone(adv) as Arc<dyn crate::sparse::Residency>);
    }
    let mut batcher = Batcher::new(data.n_train(), data.n_features, cfg.batch);
    let dropout = if cfg.dropout > 0.0 {
        Some(Dropout::new(cfg.dropout))
    } else {
        None
    };

    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut best_test = 0.0f32;
    let mut final_test = f32::NAN;

    for epoch in 0..cfg.epochs {
        let lr = cfg.lr.at(epoch);
        let timer = Timer::start();
        batcher.reset(rng);
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut n_batches = 0usize;
        while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
            let stats =
                model
                    .mlp
                    .train_step(x, y, &cfg.optimizer, lr, dropout.as_ref(), &mut ws, rng);
            loss_sum += stats.loss as f64;
            acc_sum += stats.accuracy as f64;
            n_batches += 1;
        }
        let train_secs = timer.secs();

        // fused evolution / importance — identical dispatch (and RNG
        // consumption) to the in-RAM loop, on the streaming path
        let imp_due = cfg.importance.as_ref().filter(|imp| imp.due(epoch));
        let evo_due = cfg.evolution.as_ref().filter(|_| epoch + 1 < cfg.epochs);
        match (evo_due, imp_due) {
            (Some(evo), imp) => {
                let stats = evolve_epoch(model, Some(evo), imp, rng)?;
                if opts.verbose && imp.is_some() {
                    let removed: usize = stats.iter().map(|s| s.importance_pruned).sum();
                    log::info!("epoch {epoch}: importance pruning removed {removed}");
                }
            }
            (None, Some(imp)) => {
                let stats = evolve_epoch(model, None, Some(imp), rng)?;
                if opts.verbose {
                    let removed: usize = stats.iter().map(|s| s.importance_pruned).sum();
                    log::info!("epoch {epoch}: importance pruning removed {removed}");
                }
            }
            (None, None) => {}
        }
        if evo_due.is_some() || imp_due.is_some() {
            if let Some(adv) = &advisor {
                adv.set_regions(model.regions());
            }
        }

        // evaluation — same cadence and batch clamp as the in-RAM loop
        let (mut test_loss, mut test_acc) = (f32::NAN, f32::NAN);
        if cfg.eval_every > 0 && (epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs) {
            let (l, a) = model
                .mlp
                .evaluate(&data.x_test, &data.y_test, cfg.batch.max(256), &mut ws);
            test_loss = l;
            test_acc = a;
            best_test = best_test.max(a);
            final_test = a;
        }

        let log_entry = EpochLog {
            epoch,
            train_loss: (loss_sum / n_batches.max(1) as f64) as f32,
            train_accuracy: (acc_sum / n_batches.max(1) as f64) as f32,
            test_loss,
            test_accuracy: test_acc,
            weight_count: model.mlp.weight_count(),
            seconds: train_secs,
        };
        if opts.verbose {
            log::info!(
                "epoch {:>4}  loss {:.4}  train_acc {:.4}  test_acc {:.4}  weights {}",
                epoch,
                log_entry.train_loss,
                log_entry.train_accuracy,
                log_entry.test_accuracy,
                log_entry.weight_count
            );
        }
        epochs.push(log_entry);

        if opts.persist_every > 0 && (epoch + 1) % opts.persist_every == 0 {
            model.persist()?;
        }
    }

    // final reseal: training wrote values/velocity through the mappings,
    // so the CRC trailers are stale until this
    model.persist()?;
    Ok(BigTrainReport {
        end_weights: model.mlp.weight_count(),
        start_weights,
        best_test_accuracy: best_test,
        final_test_accuracy: final_test,
        epochs,
        peak_rss_bytes: vm_hwm_bytes(),
        trim_events: advisor.map_or(0, |a| a.trim_events()),
    })
}
