//! Out-of-core sparse model storage: mmap-backed layers for beyond-RAM
//! training (DESIGN.md §14).
//!
//! The paper's "bat brain" argument — a sparse network with the synapse
//! count of a bat's brain needs far less memory than its dense
//! equivalent — stops at RAM. This subsystem moves the boundary to
//! disk: every layer's CSR arrays, velocity and bias state live in one
//! durable, CRC-trailed `TSNS` segment file ([`segment`]), memory-mapped
//! and exposed to the *unmodified* kernels through the
//! [`Buf`][crate::sparse::Buf] abstraction. Model size is bounded by
//! disk; resident memory by what the kernels touch, with an optional
//! in-process eviction advisor ([`residency`]) holding RSS near a
//! configured budget.
//!
//! The module splits along the plan/data boundary:
//! * [`segment`] — the on-disk format and its durability protocol
//!   (staged `.tmp` build → seal (CRC + fsync) → atomic rename);
//! * [`model`] — [`BigModel`]: a real `SparseMlp` over mapped windows,
//!   with streaming Erdős–Rényi creation bit-identical to
//!   `SparseMlp::new`;
//! * [`evolve`] — streaming SET/importance epochs: plan in RAM
//!   (O(rows + regrowth)), rebuild into a fresh segment generation
//!   chunk by chunk, swap by rename;
//! * [`train`] — the epoch driver, RNG-identical to the in-RAM
//!   sequential driver (no model clones);
//! * [`residency`] — `/proc/self/status` accounting + the soft-budget
//!   page-drop advisor.
//!
//! Everything here assumes `usize` can index the mapped `u64` row
//! offsets, so the module is compiled only on 64-bit targets (gated in
//! `lib.rs`).

pub mod evolve;
pub mod model;
pub mod residency;
pub mod segment;
pub mod train;

pub use evolve::evolve_epoch;
pub use model::{layer_path, BigModel};
pub use residency::{vm_hwm_bytes, vm_rss_bytes, SegmentResidency};
pub use segment::{Segment, SegmentLayout};
pub use train::{train_big, BigTrainOptions, BigTrainReport};
