//! Resident-memory accounting and the segment residency advisor
//! (DESIGN.md §14.6).
//!
//! Out-of-core training is only "out of core" if the kernel is actually
//! allowed to drop mapped pages: without pressure, Linux happily keeps
//! the whole working set cached and RSS tracks model size. The
//! [`SegmentResidency`] advisor supplies that pressure from inside the
//! process — after a layer's optimizer update (the last touch of its
//! pages for the batch) it checks `VmRSS` against a **soft budget** and,
//! when over, flushes and drops that layer's mapped segment
//! (`msync(MS_SYNC)` then `MADV_DONTNEED`). `MS_SYNC`-before-drop keeps
//! the protocol obviously lossless: every page handed back to the kernel
//! is already durable in the file, regardless of writeback timing.
//!
//! The advisor is correctness-neutral by the [`Residency`] contract: it
//! only syncs and advises, never mutates data, so the bit-exact parity
//! suite runs with and without it installed. `/proc/self/status` is read
//! at most every `check_every` hooks (an atomic counter — the hooks are
//! called from kernel worker context), so steady-state overhead is a few
//! atomic ops per batch.
//!
//! [`vm_rss_bytes`] / [`vm_hwm_bytes`] parse `/proc/self/status` and are
//! also the measurement protocol of the extreme-scale bench (BENCH_7)
//! and the `extreme-smoke` CI job: *peak* RSS (`VmHWM`) is asserted
//! against the budget, so a transient excursion cannot hide.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::sparse::{MapRegion, Residency};

/// `VmRSS` of this process in bytes (`None` off-Linux or on parse
/// failure).
pub fn vm_rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS:").map(|kb| kb * 1024)
}

/// `VmHWM` (peak RSS) of this process in bytes.
pub fn vm_hwm_bytes() -> Option<u64> {
    proc_status_kb("VmHWM:").map(|kb| kb * 1024)
}

/// Value of a `key:  <n> kB` line in `/proc/self/status`.
fn proc_status_kb(key: &str) -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

/// Keeps training RSS near a soft budget by dropping a layer's mapped
/// segment pages right after its optimizer update whenever `VmRSS`
/// exceeds the budget. Install via `Workspace::residency`; refresh with
/// [`SegmentResidency::set_regions`] after evolution swaps in new
/// segment generations.
pub struct SegmentResidency {
    /// Per-layer mapped regions (generation-current; a `Mutex` because
    /// hooks fire from kernel worker threads while the training loop
    /// replaces entries after evolution).
    regions: Mutex<Vec<Arc<MapRegion>>>,
    /// Soft RSS budget in bytes.
    soft_budget: u64,
    /// Consult `/proc` once per this many hook calls.
    check_every: usize,
    counter: AtomicUsize,
    /// Trim events (test/bench observability).
    trims: AtomicUsize,
}

impl std::fmt::Debug for SegmentResidency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SegmentResidency")
            .field("soft_budget", &self.soft_budget)
            .field("check_every", &self.check_every)
            .field("trims", &self.trims.load(Ordering::Relaxed))
            .finish()
    }
}

impl SegmentResidency {
    /// Advisor over `regions` (layer order) with a soft RSS budget in
    /// bytes. `check_every` of 0 checks on every hook.
    pub fn new(regions: Vec<Arc<MapRegion>>, soft_budget: u64, check_every: usize) -> Self {
        SegmentResidency {
            regions: Mutex::new(regions),
            soft_budget,
            check_every: check_every.max(1),
            counter: AtomicUsize::new(0),
            trims: AtomicUsize::new(0),
        }
    }

    /// Swap in the current segment generations (call after evolution).
    pub fn set_regions(&self, regions: Vec<Arc<MapRegion>>) {
        *self.regions.lock().unwrap() = regions;
    }

    /// Number of sync+drop events so far.
    pub fn trim_events(&self) -> usize {
        self.trims.load(Ordering::Relaxed)
    }

    fn maybe_trim(&self, l: usize) {
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.check_every != 0 {
            return;
        }
        let Some(rss) = vm_rss_bytes() else { return };
        if rss <= self.soft_budget {
            return;
        }
        let region = {
            let regions = self.regions.lock().unwrap();
            match regions.get(l) {
                Some(r) => Arc::clone(r),
                None => return,
            }
        };
        // flush-then-drop: pages dirtied by this batch's update become
        // durable before the mapping releases them
        if region.sync(0, region.len()).is_ok() {
            region.advise_dontneed(0, region.len());
            self.trims.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl Residency for SegmentResidency {
    fn after_forward(&self, _l: usize) {
        // forward-faulted pages are about to be re-read by the backward
        // pass — dropping them here would double the fault traffic
    }

    fn after_update(&self, l: usize) {
        self.maybe_trim(l);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_os = "linux")]
    #[test]
    fn proc_status_parsers_return_plausible_values() {
        let rss = vm_rss_bytes().expect("VmRSS readable on Linux");
        let hwm = vm_hwm_bytes().expect("VmHWM readable on Linux");
        assert!(rss > 0);
        assert!(hwm >= rss, "peak {hwm} below current {rss}");
    }

    #[test]
    fn over_budget_hook_trims_and_counts() {
        // budget 0 forces every check over budget; empty region list
        // means the trim is a no-op lookup but the counter cadence and
        // thread-safety still exercise
        let adv = SegmentResidency::new(Vec::new(), 0, 1);
        adv.after_update(0);
        adv.after_forward(0);
        assert_eq!(adv.trim_events(), 0, "no region -> no trim event");
        // an unbounded budget never trims
        let adv = SegmentResidency::new(Vec::new(), u64::MAX, 1);
        adv.after_update(0);
        assert_eq!(adv.trim_events(), 0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn trim_drops_resident_pages_of_a_mapped_region() {
        use crate::sparse::MapRegion;
        let path = std::env::temp_dir()
            .join(format!("tsnn_residency_{}.bin", std::process::id()));
        let len = 4usize << 20;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        file.set_len(len as u64).unwrap();
        let region = MapRegion::map_file(&file, len).unwrap();
        // dirty every page through a byte window, then trim with budget 0
        {
            let mut buf = crate::sparse::Buf::Mapped(
                crate::sparse::MapSlice::<u8>::new(Arc::clone(&region), 0, len).unwrap(),
            );
            for b in buf.as_mut_slice().iter_mut().step_by(4096) {
                *b = 1;
            }
        }
        let adv = SegmentResidency::new(vec![Arc::clone(&region)], 0, 1);
        adv.after_update(0);
        assert_eq!(adv.trim_events(), 1);
        // the data survives the drop (it was synced first)
        {
            let buf = crate::sparse::Buf::Mapped(
                crate::sparse::MapSlice::<u8>::new(Arc::clone(&region), 0, len).unwrap(),
            );
            assert!(buf.as_slice().iter().step_by(4096).all(|&b| b == 1));
        }
        drop(region);
        std::fs::remove_file(&path).unwrap();
    }
}
