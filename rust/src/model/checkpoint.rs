//! Sparse checkpoint format: save/load a [`SparseMlp`] without ever
//! materialising dense weights.
//!
//! Layout (little-endian):
//!   magic "TSNN" | version u32 | json header length u32 | json header |
//!   per layer: row_ptr (u64s), col_idx (u32s), values (f32s),
//!              bias (f32s), velocity (f32s), bias_velocity (f32s)
//!
//! The JSON header carries sizes, activations and nnz counts so a loader
//! can pre-validate before touching the bulk arrays.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Result, TsnnError};
use crate::nn::Activation;
use crate::sparse::CsrMatrix;
use crate::util::json::{self, Json};

use super::layer::SparseLayer;
use super::mlp::SparseMlp;

const MAGIC: &[u8; 4] = b"TSNN";
const VERSION: u32 = 1;

/// Largest JSON header a well-formed checkpoint can carry (the header
/// is a few numbers per layer — 16 MiB is orders of magnitude of slack).
const MAX_HEADER_BYTES: usize = 16 << 20;

pub(crate) fn act_name(a: &Activation) -> String {
    match a {
        Activation::Relu => "relu".into(),
        Activation::LeakyRelu { alpha } => format!("lrelu:{alpha}"),
        Activation::AllRelu { alpha } => format!("allrelu:{alpha}"),
        Activation::Linear => "linear".into(),
    }
}

// --- shared little-endian bulk-array writers -------------------------------
//
// The coordinator wire format (`coordinator/transport/wire.rs`) reuses these
// so checkpoints and transport frames stay byte-compatible per array: f32 /
// u32 / u64 little-endian, row_ptr widened to u64.

pub(crate) fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_f32_slice(w: &mut impl Write, vs: &[f32]) -> Result<()> {
    for &v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn write_u32_slice(w: &mut impl Write, vs: &[u32]) -> Result<()> {
    for &v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn write_usize_slice_as_u64(w: &mut impl Write, vs: &[usize]) -> Result<()> {
    for &v in vs {
        w.write_all(&(v as u64).to_le_bytes())?;
    }
    Ok(())
}

/// Save a model to `path`.
pub fn save(mlp: &SparseMlp, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;

    let header = json::obj(vec![
        (
            "sizes",
            Json::Arr(mlp.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        (
            "activations",
            Json::Arr(
                mlp.layers
                    .iter()
                    .map(|l| Json::Str(act_name(&l.activation)))
                    .collect(),
            ),
        ),
        (
            "nnz",
            Json::Arr(
                mlp.layers
                    .iter()
                    .map(|l| Json::Num(l.weights.nnz() as f64))
                    .collect(),
            ),
        ),
    ]);
    let hbytes = header.dump().into_bytes();
    write_u32(&mut w, hbytes.len() as u32)?;
    w.write_all(&hbytes)?;

    for layer in &mlp.layers {
        write_usize_slice_as_u64(&mut w, &layer.weights.row_ptr)?;
        write_u32_slice(&mut w, &layer.weights.col_idx)?;
        write_f32_slice(&mut w, &layer.weights.values)?;
        write_f32_slice(&mut w, &layer.bias)?;
        write_f32_slice(&mut w, &layer.velocity)?;
        write_f32_slice(&mut w, &layer.bias_velocity)?;
    }
    w.flush()?;
    Ok(())
}

pub(crate) fn read_exact4(r: &mut impl Read) -> Result<[u8; 4]> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(b)
}

pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_exact4(r)?))
}

pub(crate) fn read_f32_vec(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn read_u32_vec(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn read_u64_vec(r: &mut impl Read, n: usize) -> Result<Vec<u64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Load a model from `path`.
pub fn load(path: &Path) -> Result<SparseMlp> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let magic = read_exact4(&mut r)?;
    if &magic != MAGIC {
        return Err(TsnnError::Checkpoint("bad magic".into()));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(TsnnError::Checkpoint(format!("unsupported version {version}")));
    }
    let hlen = read_u32(&mut r)? as usize;
    // sanity-cap before allocating: a truncated or corrupt length field
    // must surface as a typed error, not an OOM attempt
    if hlen > MAX_HEADER_BYTES {
        return Err(TsnnError::Checkpoint(format!(
            "implausible header length {hlen}"
        )));
    }
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = json::parse(
        std::str::from_utf8(&hbytes).map_err(|_| TsnnError::Checkpoint("header utf8".into()))?,
    )
    .map_err(TsnnError::Checkpoint)?;

    let sizes: Vec<usize> = header
        .get("sizes")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing sizes".into()))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let acts: Vec<Activation> = header
        .get("activations")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing activations".into()))?
        .iter()
        .filter_map(|v| v.as_str().and_then(Activation::parse))
        .collect();
    let nnzs: Vec<usize> = header
        .get("nnz")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing nnz".into()))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let n_layers = sizes.len().saturating_sub(1);
    if acts.len() != n_layers || nnzs.len() != n_layers || n_layers == 0 {
        return Err(TsnnError::Checkpoint("inconsistent header".into()));
    }

    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (n_in, n_out) = (sizes[l], sizes[l + 1]);
        let nnz = nnzs[l];
        // a corrupt header must not drive the bulk-array allocations
        if nnz > n_in.saturating_mul(n_out) {
            return Err(TsnnError::Checkpoint(format!(
                "layer {l}: nnz {nnz} exceeds {n_in}x{n_out}"
            )));
        }
        let row_ptr: Vec<usize> = read_u64_vec(&mut r, n_in + 1)?
            .into_iter()
            .map(|v| v as usize)
            .collect();
        let col_idx = read_u32_vec(&mut r, nnz)?;
        let values = read_f32_vec(&mut r, nnz)?;
        let bias = read_f32_vec(&mut r, n_out)?;
        let velocity = read_f32_vec(&mut r, nnz)?;
        let bias_velocity = read_f32_vec(&mut r, n_out)?;
        let weights = CsrMatrix {
            n_rows: n_in,
            n_cols: n_out,
            row_ptr,
            col_idx,
            values,
        };
        weights
            .validate()
            .map_err(|e| TsnnError::Checkpoint(format!("layer {l}: {e}")))?;
        layers.push(SparseLayer {
            weights,
            bias,
            velocity,
            bias_velocity,
            activation: acts[l],
            srelu: None,
        });
    }
    Ok(SparseMlp { sizes, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::WeightInit;
    use crate::util::Rng;

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Rng::new(42);
        let mut mlp = SparseMlp::new(
            &[10, 20, 5],
            4.0,
            Activation::AllRelu { alpha: 0.75 },
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        // make state non-trivial
        for l in &mut mlp.layers {
            for (i, v) in l.velocity.iter_mut().enumerate() {
                *v = i as f32 * 0.1;
            }
            for (i, b) in l.bias.iter_mut().enumerate() {
                *b = i as f32;
            }
        }
        let dir = std::env::temp_dir().join("tsnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.tsnn");
        save(&mlp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.sizes, mlp.sizes);
        for (a, b) in loaded.layers.iter().zip(mlp.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.velocity, b.velocity);
            assert_eq!(a.activation, b.activation);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("tsnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tsnn");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
