//! Sparse checkpoint format: save/load a [`SparseMlp`] without ever
//! materialising dense weights.
//!
//! Layout (little-endian):
//!   magic "TSNN" | version u32 | json header length u32 | json header |
//!   per layer: row_ptr (u64s), col_idx (u32s), values (f32s),
//!              bias (f32s), velocity (f32s), bias_velocity (f32s)
//!   | crc32 u32 (version >= 2)
//!
//! The JSON header carries sizes, activations and nnz counts so a loader
//! can pre-validate before touching the bulk arrays.
//!
//! Durability protocol (DESIGN.md §13.1): `save` writes the whole image
//! to `PATH.tmp`, fsyncs it, renames it over `PATH`, and fsyncs the
//! parent directory — a crash at any point leaves either the old or the
//! new checkpoint, never a torn one. Version 2 appends a CRC-32 trailer
//! over everything before it; `load` verifies the trailer before parsing
//! and reports [`TsnnError::ChecksumMismatch`] on torn writes / bit rot.
//! Version-1 files (pre-trailer) still load.

use std::io::{Cursor, Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{Result, TsnnError};
use crate::nn::Activation;
use crate::sparse::CsrMatrix;
use crate::util::crc::crc32;
use crate::util::json::{self, Json};

use super::layer::SparseLayer;
use super::mlp::SparseMlp;

const MAGIC: &[u8; 4] = b"TSNN";
const VERSION: u32 = 2;

/// Largest JSON header a well-formed checkpoint can carry (the header
/// is a few numbers per layer — 16 MiB is orders of magnitude of slack).
const MAX_HEADER_BYTES: usize = 16 << 20;

pub(crate) fn act_name(a: &Activation) -> String {
    match a {
        Activation::Relu => "relu".into(),
        Activation::LeakyRelu { alpha } => format!("lrelu:{alpha}"),
        Activation::AllRelu { alpha } => format!("allrelu:{alpha}"),
        Activation::Linear => "linear".into(),
    }
}

// --- shared little-endian bulk-array writers -------------------------------
//
// The coordinator wire format (`coordinator/transport/wire.rs`) and the
// train-state format (`train/state.rs`) reuse these so checkpoints and
// transport frames stay byte-compatible per array: f32 / u32 / u64
// little-endian, row_ptr widened to u64.

pub(crate) fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_f32(w: &mut impl Write, v: f32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_f64(w: &mut impl Write, v: f64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_f32_slice(w: &mut impl Write, vs: &[f32]) -> Result<()> {
    for &v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn write_u32_slice(w: &mut impl Write, vs: &[u32]) -> Result<()> {
    for &v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn write_usize_slice_as_u64(w: &mut impl Write, vs: &[usize]) -> Result<()> {
    for &v in vs {
        w.write_all(&(v as u64).to_le_bytes())?;
    }
    Ok(())
}

/// Serialize the model image (json header + bulk arrays) — everything
/// between the magic/version prefix and the CRC trailer.
pub(crate) fn write_model(w: &mut impl Write, mlp: &SparseMlp) -> Result<()> {
    let header = json::obj(vec![
        (
            "sizes",
            Json::Arr(mlp.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
        ),
        (
            "activations",
            Json::Arr(
                mlp.layers
                    .iter()
                    .map(|l| Json::Str(act_name(&l.activation)))
                    .collect(),
            ),
        ),
        (
            "nnz",
            Json::Arr(
                mlp.layers
                    .iter()
                    .map(|l| Json::Num(l.weights.nnz() as f64))
                    .collect(),
            ),
        ),
    ]);
    let hbytes = header.dump().into_bytes();
    write_u32(w, hbytes.len() as u32)?;
    w.write_all(&hbytes)?;

    for layer in &mlp.layers {
        write_usize_slice_as_u64(w, &layer.weights.row_ptr)?;
        write_u32_slice(w, &layer.weights.col_idx)?;
        write_f32_slice(w, &layer.weights.values)?;
        write_f32_slice(w, &layer.bias)?;
        write_f32_slice(w, &layer.velocity)?;
        write_f32_slice(w, &layer.bias_velocity)?;
    }
    Ok(())
}

/// Where `save` stages its image before the atomic rename.
pub(crate) fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Durably land `image` at `path`: append a CRC-32 trailer over the
/// image, write to `PATH.tmp`, fsync, rename over `PATH`, fsync the
/// parent directory. A crash anywhere leaves the previous `PATH` intact.
pub(crate) fn write_durable(path: &Path, mut image: Vec<u8>) -> Result<()> {
    let crc = crc32(&image);
    image.extend_from_slice(&crc.to_le_bytes());
    let tmp = tmp_path(path);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&image)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        // directory fsync makes the rename itself durable; best-effort on
        // filesystems that refuse to open directories
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Save a model to `path` (atomic, CRC-trailed — never clobbers the
/// previous checkpoint on a mid-write crash).
pub fn save(mlp: &SparseMlp, path: &Path) -> Result<()> {
    let mut image = Vec::new();
    image.extend_from_slice(MAGIC);
    write_u32(&mut image, VERSION)?;
    write_model(&mut image, mlp)?;
    write_durable(path, image)
}

pub(crate) fn read_exact4(r: &mut impl Read) -> Result<[u8; 4]> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(b)
}

pub(crate) fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_exact4(r)?))
}

pub(crate) fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_f32(r: &mut impl Read) -> Result<f32> {
    Ok(f32::from_le_bytes(read_exact4(r)?))
}

pub(crate) fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub(crate) fn read_f32_vec(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn read_u32_vec(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn read_u64_vec(r: &mut impl Read, n: usize) -> Result<Vec<u64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Read a full durable file: check `magic`, return `(version, bytes)`.
/// The caller decides per-version whether a CRC trailer is expected and
/// calls [`checked_image`] to verify + strip it.
pub(crate) fn read_framed(path: &Path, magic: &[u8; 4]) -> Result<(u32, Vec<u8>)> {
    let bytes = std::fs::read(path)?;
    let mut r = Cursor::new(&bytes[..]);
    let m = read_exact4(&mut r)?;
    if &m != magic {
        return Err(TsnnError::Checkpoint("bad magic".into()));
    }
    let version = read_u32(&mut r)?;
    Ok((version, bytes))
}

/// Verify the CRC-32 trailer of a durable image and return the body
/// bounds `(start, end)` — `bytes[8..len-4]`, i.e. everything after the
/// magic/version prefix and before the trailer.
pub(crate) fn checked_image(bytes: &[u8]) -> Result<(usize, usize)> {
    if bytes.len() < 12 {
        return Err(TsnnError::ChecksumMismatch(
            "file too short for its integrity trailer".into(),
        ));
    }
    let body_end = bytes.len() - 4;
    let stored = u32::from_le_bytes([
        bytes[body_end],
        bytes[body_end + 1],
        bytes[body_end + 2],
        bytes[body_end + 3],
    ]);
    let computed = crc32(&bytes[..body_end]);
    if stored != computed {
        return Err(TsnnError::ChecksumMismatch(format!(
            "stored {stored:#010x} != computed {computed:#010x} (torn write or corruption)"
        )));
    }
    Ok((8, body_end))
}

/// Parse the model image (json header + bulk arrays) from a reader.
pub(crate) fn read_model(r: &mut impl Read) -> Result<SparseMlp> {
    let hlen = read_u32(r)? as usize;
    // sanity-cap before allocating: a truncated or corrupt length field
    // must surface as a typed error, not an OOM attempt
    if hlen > MAX_HEADER_BYTES {
        return Err(TsnnError::Checkpoint(format!(
            "implausible header length {hlen}"
        )));
    }
    let mut hbytes = vec![0u8; hlen];
    r.read_exact(&mut hbytes)?;
    let header = json::parse(
        std::str::from_utf8(&hbytes).map_err(|_| TsnnError::Checkpoint("header utf8".into()))?,
    )
    .map_err(TsnnError::Checkpoint)?;

    let sizes: Vec<usize> = header
        .get("sizes")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing sizes".into()))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let acts: Vec<Activation> = header
        .get("activations")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing activations".into()))?
        .iter()
        .filter_map(|v| v.as_str().and_then(Activation::parse))
        .collect();
    let nnzs: Vec<usize> = header
        .get("nnz")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| TsnnError::Checkpoint("missing nnz".into()))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let n_layers = sizes.len().saturating_sub(1);
    if acts.len() != n_layers || nnzs.len() != n_layers || n_layers == 0 {
        return Err(TsnnError::Checkpoint("inconsistent header".into()));
    }

    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (n_in, n_out) = (sizes[l], sizes[l + 1]);
        let nnz = nnzs[l];
        // a corrupt header must not drive the bulk-array allocations
        if nnz > n_in.saturating_mul(n_out) {
            return Err(TsnnError::Checkpoint(format!(
                "layer {l}: nnz {nnz} exceeds {n_in}x{n_out}"
            )));
        }
        let row_ptr: Vec<usize> = read_u64_vec(r, n_in + 1)?
            .into_iter()
            .map(|v| v as usize)
            .collect();
        let col_idx = read_u32_vec(r, nnz)?;
        let values = read_f32_vec(r, nnz)?;
        let bias = read_f32_vec(r, n_out)?;
        let velocity = read_f32_vec(r, nnz)?;
        let bias_velocity = read_f32_vec(r, n_out)?;
        let weights = CsrMatrix {
            n_rows: n_in,
            n_cols: n_out,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            values: values.into(),
        };
        weights
            .validate()
            .map_err(|e| TsnnError::Checkpoint(format!("layer {l}: {e}")))?;
        layers.push(SparseLayer {
            weights,
            bias,
            velocity: velocity.into(),
            bias_velocity,
            activation: acts[l],
            srelu: None,
        });
    }
    Ok(SparseMlp { sizes, layers })
}

/// Load a model from `path`. Version 2 verifies the CRC-32 trailer
/// first; version-1 files (no trailer) still load.
pub fn load(path: &Path) -> Result<SparseMlp> {
    let (version, bytes) = read_framed(path, MAGIC)?;
    match version {
        1 => {
            let mut r = Cursor::new(&bytes[8..]);
            read_model(&mut r)
        }
        2 => {
            let (start, end) = checked_image(&bytes)?;
            let body = &bytes[start..end];
            let mut r = Cursor::new(body);
            let model = read_model(&mut r)?;
            if (r.position() as usize) != body.len() {
                return Err(TsnnError::Checkpoint("trailing bytes after model".into()));
            }
            Ok(model)
        }
        v => Err(TsnnError::Checkpoint(format!("unsupported version {v}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::WeightInit;
    use crate::util::Rng;

    /// Header-level guard for beyond-u32 models (DESIGN.md §14): row
    /// offsets and nnz totals past `u32::MAX` must survive the u64
    /// writer/reader pair untruncated. No multi-gigabyte layer is ever
    /// materialised — only the 8-byte codec itself is on trial.
    #[test]
    fn row_offsets_past_u32_max_roundtrip_through_the_u64_codec() {
        let offsets: Vec<usize> = vec![
            0,
            1,
            u32::MAX as usize - 1,
            u32::MAX as usize,
            u32::MAX as usize + 1,
            1usize << 33,
            (1usize << 40) + 12_345,
            usize::MAX >> 1,
        ];
        let mut buf = Vec::new();
        write_usize_slice_as_u64(&mut buf, &offsets).unwrap();
        assert_eq!(buf.len(), offsets.len() * 8);
        let mut r = Cursor::new(&buf[..]);
        let back = read_u64_vec(&mut r, offsets.len()).unwrap();
        for (&o, &b) in offsets.iter().zip(back.iter()) {
            assert_eq!(o as u64, b, "u64 codec truncated {o}");
        }

        // an nnz total past u32::MAX through the scalar u64 field
        let nnz = (1u64 << 35) + 7;
        let mut buf = Vec::new();
        write_u64(&mut buf, nnz).unwrap();
        let mut r = Cursor::new(&buf[..]);
        assert_eq!(read_u64_vec(&mut r, 1).unwrap(), vec![nnz]);
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Rng::new(42);
        let mut mlp = SparseMlp::new(
            &[10, 20, 5],
            4.0,
            Activation::AllRelu { alpha: 0.75 },
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        // make state non-trivial
        for l in &mut mlp.layers {
            for (i, v) in l.velocity.iter_mut().enumerate() {
                *v = i as f32 * 0.1;
            }
            for (i, b) in l.bias.iter_mut().enumerate() {
                *b = i as f32;
            }
        }
        let dir = std::env::temp_dir().join("tsnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.tsnn");
        save(&mlp, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.sizes, mlp.sizes);
        for (a, b) in loaded.layers.iter().zip(mlp.layers.iter()) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.velocity, b.velocity);
            assert_eq!(a.activation, b.activation);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("tsnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tsnn");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_does_not_leave_tmp_files() {
        let mut rng = Rng::new(3);
        let mlp = SparseMlp::new(
            &[6, 4, 2],
            2.0,
            Activation::Relu,
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        let dir = std::env::temp_dir().join("tsnn_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.tsnn");
        save(&mlp, &path).unwrap();
        assert!(path.exists());
        assert!(!tmp_path(&path).exists());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn version1_files_without_trailer_still_load() {
        let mut rng = Rng::new(5);
        let mlp = SparseMlp::new(
            &[8, 6, 3],
            3.0,
            Activation::Relu,
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        // hand-assemble a v1 image: no trailer, version field = 1
        let mut image = Vec::new();
        image.extend_from_slice(MAGIC);
        write_u32(&mut image, 1).unwrap();
        write_model(&mut image, &mlp).unwrap();
        let dir = std::env::temp_dir().join("tsnn_ckpt_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.tsnn");
        std::fs::write(&path, &image).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.sizes, mlp.sizes);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flipped_bit_is_a_checksum_mismatch() {
        let mut rng = Rng::new(7);
        let mlp = SparseMlp::new(
            &[8, 6, 3],
            3.0,
            Activation::Relu,
            &WeightInit::Xavier,
            &mut rng,
        )
        .unwrap();
        let dir = std::env::temp_dir().join("tsnn_ckpt_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flip.tsnn");
        save(&mlp, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        match load(&path) {
            Err(TsnnError::ChecksumMismatch(_)) => {}
            other => panic!("expected checksum mismatch, got {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }
}
