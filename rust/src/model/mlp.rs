//! The truly-sparse multilayer perceptron: forward, backward, train step.
//!
//! All buffers live in a reusable [`Workspace`] so the steady-state epoch
//! loop performs no allocation — one of the §Perf items. The backward
//! pass produces weight gradients *only on existing links* (aligned with
//! each layer's CSR values), which is the memory property that separates
//! truly-sparse training from masked-dense training; above layer 0 the
//! weight and input gradients come out of ONE fused CSR traversal per
//! layer (DESIGN.md §5), and the forward pass applies activations out of
//! place (`pre[l] → act[l+1]`) so pre-activations survive for backprop
//! without a copy.

use std::sync::Arc;

use crate::error::{Result, TsnnError};
use crate::nn::{accuracy, softmax_cross_entropy, Activation, Dropout, MomentumSgd};
use crate::sparse::{ops, Exec, Residency, WeightInit, WorkerPool};
use crate::util::Rng;

use super::layer::SparseLayer;

/// Sparse MLP: `sizes[0] → sizes[1] → … → sizes[L]` with sparse layers.
#[derive(Debug, Clone)]
pub struct SparseMlp {
    /// Layer dimensions (length L+1).
    pub sizes: Vec<usize>,
    /// The L sparse layers.
    pub layers: Vec<SparseLayer>,
}

/// Reusable buffers for forward/backward over a fixed max batch size.
#[derive(Default)]
pub struct Workspace {
    /// Pre-activations per layer: pre[l] is [batch, sizes[l+1]].
    pub pre: Vec<Vec<f32>>,
    /// Post-activations: act[l] is the input to layer l; act[0] = x copy.
    pub act: Vec<Vec<f32>>,
    /// Logits gradient / layer delta buffers (double-buffered).
    delta_a: Vec<f32>,
    delta_b: Vec<f32>,
    /// Aligned weight gradients per layer.
    pub grad_w: Vec<Vec<f32>>,
    /// Bias gradients per layer.
    pub grad_b: Vec<Vec<f32>>,
    /// Dropout masks per hidden layer.
    drop_masks: Vec<Vec<f32>>,
    /// SReLU parameter gradients per layer (None for fixed activations).
    pub srelu_grads: Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>>,
    /// Loss-gradient buffer (reused across train steps AND evaluation
    /// batches — zero steady-state allocation everywhere).
    dlogits: Vec<f32>,
    /// Worker budget for the sharded sparse kernels: `0` = one per
    /// available core, `1` = sequential, `n` = at most n threads. The
    /// sharded kernels produce exactly the sequential results (DESIGN.md
    /// §4), so this is a pure speed knob. Coordinator workers set it to
    /// their share of the machine so K workers × kernel threads never
    /// oversubscribes cores.
    pub kernel_threads: usize,
    /// Persistent kernel worker pool (DESIGN.md §9) serving every
    /// sharded dispatch issued through this workspace — forward, fused
    /// backward, and (shared via the training loop) topology evolution.
    /// Created once per resolved budget by [`Workspace::ensure_pool`];
    /// one pool lives for the whole training run.
    pool: Option<Arc<WorkerPool>>,
    /// Residency advisor for mmap-backed models (DESIGN.md §14.4): the
    /// train/eval loops report when they are done touching a layer's
    /// arrays for the current batch, and the advisor may trim resident
    /// mapped pages. `None` (the default, and always for RAM-backed
    /// models) makes every hook a no-op; installed advisors are
    /// correctness-neutral by the [`Residency`] contract.
    pub residency: Option<Arc<dyn Residency>>,
    /// Per-layer row-liveness bitmaps for the activity-gated optimizer
    /// update (DESIGN.md §14.6): bit r set ⇔ input row r of that layer
    /// may hold nonzero velocity. Owned here (not by the layer) so the
    /// bare model stays a pure function of its parameters; sized lazily
    /// by [`SparseMlp::train_step`].
    pub row_live: Vec<Vec<u64>>,
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // buffers are uninteresting noise; `Arc<dyn Residency>` has no
        // Debug, so the derive is replaced by this summary
        f.debug_struct("Workspace")
            .field("kernel_threads", &self.kernel_threads)
            .field("pooled", &self.pool.is_some())
            .field("residency", &self.residency.is_some())
            .field("layers", &self.grad_w.len())
            .finish()
    }
}

impl Workspace {
    /// Empty workspace with a kernel-shard budget (`0` = one worker per
    /// available core); buffers are sized lazily on first use, the
    /// worker pool on the first dispatch (or [`Workspace::ensure_pool`]).
    pub fn with_threads(kernel_threads: usize) -> Self {
        Workspace {
            kernel_threads,
            ..Default::default()
        }
    }

    /// Make the persistent worker pool match the current
    /// `kernel_threads` budget: created on first use, replaced if the
    /// budget changed, dropped (workers joined) at budget ≤ 1. Called
    /// automatically at every forward/backward entry, so the pool spawns
    /// exactly once per training run.
    pub fn ensure_pool(&mut self) {
        let t = ops::resolve_threads(self.kernel_threads);
        if t <= 1 {
            self.pool = None;
        } else if self.pool.as_ref().map(|p| p.threads()) != Some(t) {
            self.pool = Some(Arc::new(WorkerPool::new(t)));
        }
    }

    /// Shared handle to the persistent pool (None until a multi-thread
    /// budget is set and [`Workspace::ensure_pool`] / a dispatch ran).
    /// The training loop hands this to the evolution engine so kernels
    /// and topology evolution share one pool.
    pub fn pool(&self) -> Option<Arc<WorkerPool>> {
        self.pool.clone()
    }
}

/// One train-step report.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    /// Mean batch loss.
    pub loss: f32,
    /// Batch accuracy.
    pub accuracy: f32,
    /// Σ‖∇‖² across all weight/bias gradients (the gradient-flow metric
    /// of Fig. 5: first-order expected loss decrease per unit lr).
    pub grad_norm_sq: f32,
}

impl SparseMlp {
    /// Construct with Erdős–Rényi layers at SET ε, shared activation for
    /// hidden layers and linear output.
    pub fn new(
        sizes: &[usize],
        epsilon: f64,
        activation: Activation,
        init: &WeightInit,
        rng: &mut Rng,
    ) -> Result<Self> {
        if sizes.len() < 2 {
            return Err(TsnnError::Config("need at least input+output sizes".into()));
        }
        let n_layers = sizes.len() - 1;
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let act = if l + 1 == n_layers {
                Activation::Linear
            } else {
                activation
            };
            layers.push(SparseLayer::erdos_renyi(
                sizes[l],
                sizes[l + 1],
                epsilon,
                act,
                init,
                rng,
            ));
        }
        Ok(SparseMlp {
            sizes: sizes.to_vec(),
            layers,
        })
    }

    /// Number of layers (connections matrices).
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Output classes.
    pub fn n_classes(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Total trainable parameters (the paper's `n^W` columns).
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Total stored weights (excluding biases).
    pub fn weight_count(&self) -> usize {
        self.layers.iter().map(|l| l.weights.nnz()).sum()
    }

    /// Total neurons (the paper's headline scale metric).
    pub fn neuron_count(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Bytes of weight storage (CSR arrays + biases + velocities).
    pub fn memory_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.weights.memory_bytes() + 4 * (l.bias.len() * 2 + l.velocity.len()))
            .sum()
    }

    /// Validate every layer: CSR structural invariants plus aligned-state
    /// lengths (velocity ↔ nnz, bias/bias-velocity ↔ n_out). Used by the
    /// topology-evolution tests after structural mutations.
    pub fn validate(&self) -> Result<()> {
        for (l, layer) in self.layers.iter().enumerate() {
            layer
                .weights
                .validate()
                .map_err(|e| TsnnError::Sparse(format!("layer {l}: {e}")))?;
            if layer.velocity.len() != layer.weights.nnz() {
                return Err(TsnnError::Sparse(format!(
                    "layer {l}: velocity length {} != nnz {}",
                    layer.velocity.len(),
                    layer.weights.nnz()
                )));
            }
            if layer.bias.len() != layer.n_out() || layer.bias_velocity.len() != layer.n_out() {
                return Err(TsnnError::Sparse(format!(
                    "layer {l}: bias state length mismatch"
                )));
            }
        }
        Ok(())
    }

    /// Size a workspace for `batch` samples.
    pub fn alloc_workspace(&self, batch: usize) -> Workspace {
        let mut ws = Workspace::default();
        self.resize_workspace(&mut ws, batch);
        ws
    }

    /// (Re)size an existing workspace; no-op when already the right size.
    pub fn resize_workspace(&self, ws: &mut Workspace, batch: usize) {
        let n_layers = self.n_layers();
        ws.pre.resize(n_layers, Vec::new());
        ws.act.resize(n_layers + 1, Vec::new());
        ws.grad_w.resize(n_layers, Vec::new());
        ws.grad_b.resize(n_layers, Vec::new());
        ws.drop_masks.resize(n_layers, Vec::new());
        ws.srelu_grads.resize(n_layers, None);
        ws.act[0].resize(batch * self.sizes[0], 0.0);
        let max_width = self.sizes.iter().max().copied().unwrap_or(0);
        ws.delta_a.resize(batch * max_width, 0.0);
        ws.delta_b.resize(batch * max_width, 0.0);
        for (l, layer) in self.layers.iter().enumerate() {
            ws.pre[l].resize(batch * layer.n_out(), 0.0);
            ws.act[l + 1].resize(batch * layer.n_out(), 0.0);
            ws.grad_w[l].resize(layer.weights.nnz(), 0.0);
            ws.grad_b[l].resize(layer.n_out(), 0.0);
        }
    }

    /// Forward pass over a batch. When `dropout` is set (training mode),
    /// hidden activations are dropped with the recorded masks kept for
    /// backward. Returns a reference to the logits buffer.
    pub fn forward<'w>(
        &self,
        x: &[f32],
        batch: usize,
        ws: &'w mut Workspace,
        dropout: Option<(&Dropout, &mut Rng)>,
    ) -> &'w [f32] {
        debug_assert_eq!(x.len(), batch * self.sizes[0]);
        self.resize_workspace(ws, batch);
        ws.ensure_pool();
        ws.act[0].copy_from_slice(x);
        let n_layers = self.n_layers();
        // one Arc clone per forward keeps the pool borrow out of the
        // workspace's field borrows below
        let pool = ws.pool.clone();
        let exec = Exec::with(ws.kernel_threads, pool.as_deref());
        let mut drop = dropout;
        for (l, layer) in self.layers.iter().enumerate() {
            let n_out = layer.n_out();
            // z = x W + b  (bias folded into the kernel's pre-zero pass)
            {
                // `act` and `pre` are disjoint fields, so the split borrow
                // is safe and allocation-free.
                let (act, pre) = (&ws.act, &mut ws.pre);
                layer.forward_into(&act[l], batch, &mut pre[l], exec);
            }
            // activation out of place, pre[l] → act[l+1]: the
            // pre-activation survives for backprop without a copy
            {
                let (pre, act) = (&ws.pre, &mut ws.act);
                if let Some(srelu) = &layer.srelu {
                    srelu.apply(&pre[l], &mut act[l + 1], n_out);
                } else {
                    layer.activation.apply(&pre[l], &mut act[l + 1], l + 1);
                }
            }
            // dropout on hidden layers only
            ws.drop_masks[l].clear();
            if l + 1 < n_layers {
                if let Some((d, rng)) = drop.as_mut() {
                    let mut mask = std::mem::take(&mut ws.drop_masks[l]);
                    d.apply(&mut ws.act[l + 1], &mut mask, rng);
                    ws.drop_masks[l] = mask;
                }
            }
            // the forward pass is done with this layer's weights; an
            // installed residency advisor may trim its mapped pages
            if let Some(res) = ws.residency.as_ref() {
                res.after_forward(l);
            }
        }
        &ws.act[n_layers]
    }

    /// Backward pass given `dlogits` already stored in the workspace's
    /// delta buffer (callers use [`SparseMlp::train_step`]; exposed for
    /// the coordinator's gradient-only workers).
    ///
    /// Each hidden layer runs the fused one-pass kernel through
    /// [`SparseLayer::backward_into`]: weight gradient and input gradient
    /// come out of a single CSR traversal (DESIGN.md §5). Fills
    /// `ws.grad_w` / `ws.grad_b` (overwritten, not accumulated) and
    /// returns Σ‖∇‖².
    pub fn backward(&self, batch: usize, ws: &mut Workspace, dlogits: &[f32]) -> f32 {
        let n_layers = self.n_layers();
        debug_assert_eq!(dlogits.len(), batch * self.n_classes());
        ws.delta_a[..dlogits.len()].copy_from_slice(dlogits);
        ws.ensure_pool();
        let pool = ws.pool.clone();
        let exec = Exec::with(ws.kernel_threads, pool.as_deref());
        let mut grad_sq = 0.0f32;
        for l in (0..n_layers).rev() {
            let layer = &self.layers[l];
            let (n_in, n_out) = (layer.n_in(), layer.n_out());
            let delta_len = batch * n_out;
            let dx_len = batch * n_in;
            // fused backward: dW + bias grad + (above layer 0) dx, one
            // CSR traversal; delta_a/delta_b/grad_* are disjoint fields,
            // so the split borrows are safe and allocation-free
            layer.backward_into(
                &ws.act[l],
                &ws.delta_a[..delta_len],
                batch,
                if l > 0 {
                    Some(&mut ws.delta_b[..dx_len])
                } else {
                    None
                },
                &mut ws.grad_w[l],
                &mut ws.grad_b[l],
                exec,
            );
            grad_sq += ws.grad_w[l].iter().map(|g| g * g).sum::<f32>();
            grad_sq += ws.grad_b[l].iter().map(|g| g * g).sum::<f32>();
            if l > 0 {
                // through dropout of layer l-1's output (mask recorded at
                // forward time; empty mask means dropout was off)
                let prev = &self.layers[l - 1];
                let mask = &ws.drop_masks[l - 1];
                if !mask.is_empty() {
                    for (d, &m) in ws.delta_b[..dx_len].iter_mut().zip(mask.iter()) {
                        *d *= m;
                    }
                }
                // through activation of layer l-1 (pre-activation stored)
                if let Some(srelu) = &prev.srelu {
                    let g = srelu.backprop(
                        &ws.pre[l - 1],
                        &mut ws.delta_b[..dx_len],
                        prev.n_out(),
                    );
                    ws.srelu_grads[l - 1] = Some(g);
                } else {
                    prev.activation
                        .backprop(&ws.pre[l - 1], &mut ws.delta_b[..dx_len], l);
                }
                std::mem::swap(&mut ws.delta_a, &mut ws.delta_b);
            }
        }
        grad_sq
    }

    /// One training step: forward, loss, backward, momentum-SGD update.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        x: &[f32],
        labels: &[u32],
        opt: &MomentumSgd,
        lr: f32,
        dropout: Option<&Dropout>,
        ws: &mut Workspace,
        rng: &mut Rng,
    ) -> StepStats {
        let stats = self.compute_gradients(x, labels, dropout, ws, rng);
        if ws.row_live.len() != self.layers.len() {
            ws.row_live.resize_with(self.layers.len(), Vec::new);
        }
        for (l, layer) in self.layers.iter_mut().enumerate() {
            layer.apply_update_gated(opt, &ws.grad_w[l], &ws.grad_b[l], lr, &mut ws.row_live[l]);
            if let (Some(srelu), Some(g)) = (layer.srelu.as_mut(), ws.srelu_grads[l].take()) {
                srelu.update(&g, lr);
            }
            // the optimizer update is the step's last touch of this
            // layer's weights/velocity — the trim point for mapped models
            if let Some(res) = ws.residency.as_ref() {
                res.after_update(l);
            }
        }
        stats
    }

    /// Compute gradients for a batch WITHOUT updating weights — the
    /// coordinator's worker-side primitive (gradients are pushed to the
    /// parameter server instead). Returns stats; gradients stay in `ws`.
    pub fn compute_gradients(
        &self,
        x: &[f32],
        labels: &[u32],
        dropout: Option<&Dropout>,
        ws: &mut Workspace,
        rng: &mut Rng,
    ) -> StepStats {
        let batch = labels.len();
        let n_classes = self.n_classes();
        let drop = dropout.map(|d| (d, &mut *rng));
        self.forward(x, batch, ws, drop);
        let logits = &ws.act[self.n_layers()];
        let acc = accuracy(logits, labels, n_classes);
        let mut dlogits = std::mem::take(&mut ws.dlogits);
        dlogits.resize(batch * n_classes, 0.0);
        let loss = softmax_cross_entropy(logits, labels, n_classes, &mut dlogits);
        let grad_norm_sq = self.backward(batch, ws, &dlogits);
        ws.dlogits = dlogits;
        StepStats {
            loss,
            accuracy: acc,
            grad_norm_sq,
        }
    }

    /// Evaluate mean loss and accuracy over a full dataset in batches.
    pub fn evaluate(
        &self,
        x: &[f32],
        labels: &[u32],
        batch: usize,
        ws: &mut Workspace,
    ) -> (f32, f32) {
        let n = labels.len();
        let n_classes = self.n_classes();
        let n_feat = self.sizes[0];
        let mut total_loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        // loss-gradient buffer rides in the workspace like the training
        // path's: steady-state evaluation performs no allocation either
        let mut dlogits = std::mem::take(&mut ws.dlogits);
        let mut start = 0usize;
        while start < n {
            let end = (start + batch).min(n);
            let bsz = end - start;
            self.forward(&x[start * n_feat..end * n_feat], bsz, ws, None);
            let logits = &ws.act[self.n_layers()];
            dlogits.resize(bsz * n_classes, 0.0);
            let loss =
                softmax_cross_entropy(logits, &labels[start..end], n_classes, &mut dlogits);
            let acc = accuracy(logits, &labels[start..end], n_classes);
            total_loss += loss as f64 * bsz as f64;
            correct += acc as f64 * bsz as f64;
            seen += bsz;
            start = end;
        }
        ws.dlogits = dlogits;
        (
            (total_loss / seen.max(1) as f64) as f32,
            (correct / seen.max(1) as f64) as f32,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (SparseMlp, Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(7);
        let mlp = SparseMlp::new(
            &[12, 32, 16, 3],
            8.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        // separable toy data: class = argmax of three feature groups
        let n = 90;
        let mut x = vec![0.0f32; n * 12];
        let mut y = vec![0u32; n];
        let mut r = Rng::new(3);
        for s in 0..n {
            let c = (s % 3) as u32;
            y[s] = c;
            for f in 0..12 {
                let boost = if f / 4 == c as usize { 2.0 } else { 0.0 };
                x[s * 12 + f] = r.normal() * 0.3 + boost;
            }
        }
        (mlp, x, y)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let (mlp, x, _) = toy();
        let mut ws = mlp.alloc_workspace(90);
        let logits = mlp.forward(&x, 90, &mut ws, None);
        assert_eq!(logits.len(), 90 * 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn training_learns_toy_problem() {
        let (mut mlp, x, y) = toy();
        let mut ws = mlp.alloc_workspace(90);
        let opt = MomentumSgd {
            momentum: 0.9,
            weight_decay: 0.0,
        };
        let mut rng = Rng::new(1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..80 {
            let s = mlp.train_step(&x, &y, &opt, 0.05, None, &mut ws, &mut rng);
            first.get_or_insert(s.loss);
            last = s.loss;
        }
        assert!(
            last < first.unwrap() * 0.5,
            "loss {} -> {last}",
            first.unwrap()
        );
        let (_, acc) = mlp.evaluate(&x, &y, 32, &mut ws);
        assert!(acc > 0.8, "acc {acc}");
    }

    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Rng::new(11);
        let mlp = SparseMlp::new(
            &[5, 7, 4],
            4.0,
            Activation::LeakyRelu { alpha: 0.1 },
            &WeightInit::Normal(0.5),
            &mut rng,
        )
        .unwrap();
        let x: Vec<f32> = (0..3 * 5).map(|_| rng.normal()).collect();
        let y = vec![0u32, 2, 1];
        let mut ws = mlp.alloc_workspace(3);
        let mut r2 = Rng::new(0);
        mlp.compute_gradients(&x, &y, None, &mut ws, &mut r2);
        let loss_of = |m: &SparseMlp| {
            let mut w = m.alloc_workspace(3);
            m.forward(&x, 3, &mut w, None);
            let logits = &w.act[m.n_layers()];
            let mut d = vec![0.0f32; 3 * 4];
            softmax_cross_entropy(logits, &y, 4, &mut d)
        };
        let eps = 1e-3f32;
        for l in 0..2 {
            // check a handful of weight gradients
            let nnz = mlp.layers[l].weights.nnz();
            for k in [0, nnz / 2, nnz - 1] {
                let mut mp = mlp.clone();
                mp.layers[l].weights.values[k] += eps;
                let mut mm = mlp.clone();
                mm.layers[l].weights.values[k] -= eps;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
                let g = ws.grad_w[l][k];
                assert!((g - fd).abs() < 2e-2, "layer {l} k {k}: {g} vs {fd}");
            }
            // and a bias gradient
            let mut mp = mlp.clone();
            mp.layers[l].bias[0] += eps;
            let mut mm = mlp.clone();
            mm.layers[l].bias[0] -= eps;
            let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
            assert!((ws.grad_b[l][0] - fd).abs() < 2e-2);
        }
    }

    #[test]
    fn dropout_train_still_learns_and_eval_is_deterministic() {
        let (mut mlp, x, y) = toy();
        let mut ws = mlp.alloc_workspace(90);
        let opt = MomentumSgd::default();
        let drop = Dropout::new(0.3);
        let mut rng = Rng::new(5);
        for _ in 0..60 {
            mlp.train_step(&x, &y, &opt, 0.05, Some(&drop), &mut ws, &mut rng);
        }
        let (l1, a1) = mlp.evaluate(&x, &y, 16, &mut ws);
        let (l2, a2) = mlp.evaluate(&x, &y, 16, &mut ws);
        assert_eq!(l1, l2);
        assert_eq!(a1, a2);
        assert!(a1 > 0.6, "acc {a1}");
    }

    #[test]
    fn kernel_threads_do_not_change_forward_or_gradients() {
        let (mlp, x, y) = toy();
        let mut seq_ws = mlp.alloc_workspace(90);
        seq_ws.kernel_threads = 1;
        let mut par_ws = mlp.alloc_workspace(90);
        par_ws.kernel_threads = 8;
        let mut r1 = Rng::new(0);
        let mut r2 = Rng::new(0);
        let s1 = mlp.compute_gradients(&x, &y, None, &mut seq_ws, &mut r1);
        let s2 = mlp.compute_gradients(&x, &y, None, &mut par_ws, &mut r2);
        assert_eq!(s1.loss, s2.loss);
        assert_eq!(s1.grad_norm_sq, s2.grad_norm_sq);
        for l in 0..mlp.n_layers() {
            assert_eq!(seq_ws.grad_w[l], par_ws.grad_w[l], "layer {l} grad_w");
            assert_eq!(seq_ws.grad_b[l], par_ws.grad_b[l], "layer {l} grad_b");
        }
    }

    #[test]
    fn workspace_installs_one_persistent_pool() {
        let (mlp, x, y) = toy();
        let mut ws = mlp.alloc_workspace(90);
        ws.kernel_threads = 3;
        assert!(ws.pool().is_none(), "pool is lazy");
        let mut rng = Rng::new(0);
        mlp.compute_gradients(&x, &y, None, &mut ws, &mut rng);
        let pool = ws.pool().expect("pool installed at the first dispatch");
        assert_eq!(pool.threads(), 3);
        mlp.compute_gradients(&x, &y, None, &mut ws, &mut rng);
        assert!(
            Arc::ptr_eq(&pool, &ws.pool().unwrap()),
            "one pool lives for the whole run"
        );
        // shrinking the budget to sequential retires the pool
        ws.kernel_threads = 1;
        mlp.compute_gradients(&x, &y, None, &mut ws, &mut rng);
        assert!(ws.pool().is_none());
    }

    #[test]
    fn validate_accepts_fresh_and_rejects_misaligned() {
        let (mut mlp, _, _) = toy();
        mlp.validate().unwrap();
        mlp.layers[1].velocity.pop();
        assert!(mlp.validate().is_err());
    }

    #[test]
    fn counts_and_memory() {
        let (mlp, _, _) = toy();
        assert_eq!(mlp.neuron_count(), 12 + 32 + 16 + 3);
        assert!(mlp.param_count() > 0);
        assert!(mlp.memory_bytes() > 0);
        assert!(mlp.weight_count() < 12 * 32 + 32 * 16 + 16 * 3); // sparse
    }

    #[test]
    fn rejects_degenerate_sizes() {
        let mut rng = Rng::new(0);
        assert!(SparseMlp::new(
            &[5],
            1.0,
            Activation::Relu,
            &WeightInit::Xavier,
            &mut rng
        )
        .is_err());
    }

    #[test]
    fn evaluate_handles_ragged_last_batch() {
        let (mlp, x, y) = toy();
        let mut ws = mlp.alloc_workspace(90);
        let (l1, a1) = mlp.evaluate(&x, &y, 90, &mut ws);
        let (l2, a2) = mlp.evaluate(&x, &y, 7, &mut ws);
        assert!((l1 - l2).abs() < 1e-4);
        assert!((a1 - a2).abs() < 1e-5);
    }
}
