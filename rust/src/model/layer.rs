//! A sparsely-connected layer: CSR weights + bias + aligned optimizer
//! state + activation.

use crate::nn::{remap_aligned, Activation, MomentumSgd, SRelu};
use crate::sparse::{erdos_renyi_epsilon, ops, simd, Buf, CsrMatrix, Exec, WeightInit};
use crate::util::Rng;

/// One sparse layer of the MLP (`n_in × n_out` CSR weights).
///
/// `velocity` shares the weights' [`Buf`] backing story: RAM `Vec` on
/// the normal path, a window into the layer's mapped segment under the
/// out-of-core subsystem (DESIGN.md §14). Biases stay RAM `Vec`s —
/// they are O(n_out), negligible next to nnz, and written back to the
/// segment at seal time.
#[derive(Debug, Clone)]
pub struct SparseLayer {
    /// Sparse weights, rows = inputs.
    pub weights: CsrMatrix,
    /// Bias per output neuron.
    pub bias: Vec<f32>,
    /// Momentum velocity aligned with `weights.values`.
    pub velocity: Buf<f32>,
    /// Momentum velocity for biases.
    pub bias_velocity: Vec<f32>,
    /// Element-wise activation (ignored when `srelu` is set).
    pub activation: Activation,
    /// Optional trainable SReLU (the comparator activation).
    pub srelu: Option<SRelu>,
}

impl SparseLayer {
    /// Erdős–Rényi-initialised layer with the SET ε sparsity knob.
    pub fn erdos_renyi(
        n_in: usize,
        n_out: usize,
        epsilon: f64,
        activation: Activation,
        init: &WeightInit,
        rng: &mut Rng,
    ) -> Self {
        let weights = erdos_renyi_epsilon(n_in, n_out, epsilon, rng, init);
        let nnz = weights.nnz();
        SparseLayer {
            weights,
            bias: vec![0.0; n_out],
            velocity: vec![0.0; nnz].into(),
            bias_velocity: vec![0.0; n_out],
            activation,
            srelu: None,
        }
    }

    /// Input dimension.
    pub fn n_in(&self) -> usize {
        self.weights.n_rows
    }

    /// Output dimension.
    pub fn n_out(&self) -> usize {
        self.weights.n_cols
    }

    /// Trainable parameter count (weights + biases + SReLU params).
    pub fn param_count(&self) -> usize {
        self.weights.nnz()
            + self.bias.len()
            + self.srelu.as_ref().map(|s| s.param_count()).unwrap_or(0)
    }

    /// Name of the CSR microkernel this layer's kernels dispatch to at
    /// the process-detected ISA (observability for `tsnn inspect`; the
    /// actual dispatch happens per-call via [`Exec::isa`], DESIGN.md
    /// §11.2).
    pub fn microkernel(&self) -> &'static str {
        simd::microkernel_name(simd::detected_isa(), simd::KernelFormat::Csr)
    }

    /// Linear part of the forward pass: `pre = x · W + b` (bias broadcast
    /// into `pre` here, fused with the kernel's pre-zero requirement).
    /// `exec` is the kernel dispatch context — the workspace's persistent
    /// pool on the hot path, a scoped/sequential fallback otherwise;
    /// dispatch and crossover live in [`ops`].
    pub fn forward_into(&self, x: &[f32], batch: usize, pre: &mut [f32], exec: Exec<'_>) {
        let n_out = self.n_out();
        for b in 0..batch {
            pre[b * n_out..(b + 1) * n_out].copy_from_slice(&self.bias);
        }
        ops::spmm_forward_exec(x, batch, &self.weights, pre, exec);
    }

    /// Full backward pass through this layer in one CSR traversal
    /// (DESIGN.md §5): zeroes and fills the pattern-aligned weight
    /// gradient `grad_w` and the bias gradient `grad_b`, and — when `dx`
    /// is provided — overwrites it with the input gradient `dz · Wᵀ` via
    /// the fused kernel. Layer 0 passes `None` (no gradient flows below
    /// the input), which falls back to the weight-gradient-only kernel.
    ///
    /// Results are exactly equal to the two-kernel pair
    /// [`SparseLayer::grads_into`] + [`SparseLayer::grad_input_into`]
    /// (the parity oracle) at every thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_into(
        &self,
        x: &[f32],
        dz: &[f32],
        batch: usize,
        dx: Option<&mut [f32]>,
        grad_w: &mut [f32],
        grad_b: &mut [f32],
        exec: Exec<'_>,
    ) {
        grad_w.iter_mut().for_each(|v| *v = 0.0);
        grad_b.iter_mut().for_each(|v| *v = 0.0);
        match dx {
            Some(dx) => {
                ops::spmm_backward_fused_exec(x, dz, batch, &self.weights, dx, grad_w, exec)
            }
            None => ops::spmm_grad_weights_exec(x, dz, batch, &self.weights, grad_w, exec),
        }
        ops::bias_grad(dz, batch, self.n_out(), grad_b);
    }

    /// Input gradient through this layer: `dx = dz · Wᵀ` (overwrites `dx`).
    ///
    /// Parity oracle for the fused path — the hot path is
    /// [`SparseLayer::backward_into`].
    pub fn grad_input_into(&self, dz: &[f32], batch: usize, dx: &mut [f32], exec: Exec<'_>) {
        ops::spmm_grad_input_exec(dz, batch, &self.weights, dx, exec);
    }

    /// Pattern-aligned weight gradient and bias gradient for a batch
    /// (`grad_w` aligned with `weights.values`; both buffers zeroed here).
    ///
    /// Thin alias for [`SparseLayer::backward_into`] with `dx = None`,
    /// kept for the parity tests and gradient-only callers.
    pub fn grads_into(
        &self,
        x: &[f32],
        dz: &[f32],
        batch: usize,
        grad_w: &mut [f32],
        grad_b: &mut [f32],
        exec: Exec<'_>,
    ) {
        self.backward_into(x, dz, batch, None, grad_w, grad_b, exec);
    }

    /// Apply the optimizer to this layer's weights and biases.
    pub fn apply_update(
        &mut self,
        opt: &MomentumSgd,
        grad_w: &[f32],
        grad_b: &[f32],
        lr: f32,
    ) {
        opt.update(&mut self.weights.values, grad_w, &mut self.velocity, lr);
        opt.update_bias(&mut self.bias, grad_b, &mut self.bias_velocity, lr);
    }

    /// Activity-gated optimizer update (DESIGN.md §14.6): skip rows whose
    /// gradient is entirely zero and whose velocity is known to be all
    /// zero. For such a row the dense update is a provable no-op when
    /// `weight_decay == 0`: `v' = μ·0 − η·(0 + 0·w) = 0` bitwise (μ·±0.0
    /// keeps its sign; `x − 0.0` preserves `±0.0`) and `w' = w + ±0.0 = w`
    /// bitwise for every value the trainer can produce (no init or update
    /// path yields a `-0.0` weight: IEEE-754 `x + (−x) = +0.0`, and both
    /// init samplers end in an addition or a product with a nonzero
    /// factor). With weight decay the skip would drift (`λ·w ≠ 0`), so
    /// the gate falls back to the dense path.
    ///
    /// `row_live` is a caller-owned bitmap of "this row may hold nonzero
    /// velocity", one bit per input row; it is resized (conservatively
    /// all-live) on first use. Bits stay conservative across topology
    /// evolution: surviving links keep their velocity and new links start
    /// at zero, so a clear bit can never become wrong.
    ///
    /// For mmap-backed models this is what makes out-of-core training
    /// possible at all: the dense update touches every values/velocity
    /// page of every layer on every step, pinning peak RSS at the full
    /// model size no matter what the residency advisor trims. The gate
    /// leaves pages of inactive input rows untouched, so a wide-sparse
    /// input layer stays on disk.
    pub fn apply_update_gated(
        &mut self,
        opt: &MomentumSgd,
        grad_w: &[f32],
        grad_b: &[f32],
        lr: f32,
        row_live: &mut Vec<u64>,
    ) {
        if opt.weight_decay != 0.0 {
            self.apply_update(opt, grad_w, grad_b, lr);
            return;
        }
        let n_rows = self.weights.n_rows;
        let words = n_rows.div_ceil(64);
        if row_live.len() != words {
            row_live.clear();
            row_live.resize(words, u64::MAX);
        }
        let w = &mut self.weights;
        let row_ptr = &w.row_ptr;
        let values: &mut [f32] = &mut w.values;
        let velocity: &mut [f32] = &mut self.velocity;
        for r in 0..n_rows {
            let (s, e) = (row_ptr[r], row_ptr[r + 1]);
            let live = (row_live[r >> 6] >> (r & 63)) & 1 != 0;
            if !live && grad_w[s..e].iter().all(|&g| g == 0.0) {
                continue;
            }
            opt.update(&mut values[s..e], &grad_w[s..e], &mut velocity[s..e], lr);
            if velocity[s..e].iter().any(|&v| v != 0.0) {
                row_live[r >> 6] |= 1u64 << (r & 63);
            } else {
                row_live[r >> 6] &= !(1u64 << (r & 63));
            }
        }
        opt.update_bias(&mut self.bias, grad_b, &mut self.bias_velocity, lr);
    }

    /// Rebuild aligned state after a structural change described by
    /// `old_index_of_new` (see [`remap_aligned`]). New links start with
    /// zero velocity.
    pub fn remap_state(&mut self, old_index_of_new: &[Option<usize>]) {
        self.velocity = remap_aligned(&self.velocity, old_index_of_new, 0.0).into();
        debug_assert_eq!(self.velocity.len(), self.weights.nnz());
    }

    /// Drop entries by storage index predicate, keeping velocity aligned.
    /// Returns number of removed entries.
    pub fn retain_entries(&mut self, keep: impl FnMut(usize) -> bool) -> usize {
        let before = self.weights.nnz();
        let kept = self.weights.retain(keep);
        let vel: Vec<f32> = kept.iter().map(|&k| self.velocity[k]).collect();
        self.velocity = vel.into();
        before - self.weights.nnz()
    }

    /// Swap in fully-rebuilt storage (`row_ptr`/`col_idx`/`values` plus
    /// the aligned `velocity`) produced by the evolution engine's
    /// workspace (DESIGN.md §8), leaving the previous arrays in the
    /// passed buffers for reuse next epoch — no clone, no COO rebuild.
    ///
    /// Callers guarantee the new arrays form a valid CSR for this layer's
    /// shape with `velocity` aligned to `values` (checked in debug
    /// builds).
    pub fn swap_storage(
        &mut self,
        row_ptr: &mut Vec<usize>,
        col_idx: &mut Vec<u32>,
        values: &mut Vec<f32>,
        velocity: &mut Vec<f32>,
    ) {
        debug_assert_eq!(row_ptr.len(), self.weights.n_rows + 1);
        debug_assert_eq!(col_idx.len(), values.len());
        debug_assert_eq!(velocity.len(), values.len());
        self.weights.row_ptr.swap_vec(row_ptr);
        self.weights.col_idx.swap_vec(col_idx);
        self.weights.values.swap_vec(values);
        self.velocity.swap_vec(velocity);
        debug_assert!(self.weights.validate().is_ok());
    }

    /// Insert new links (currently-empty positions), giving them zero
    /// velocity and the provided weight values.
    pub fn insert_entries(&mut self, additions: Vec<(u32, u32, f32)>) -> crate::error::Result<()> {
        let n_add = additions.len();
        let old_to_new = self.weights.insert(additions)?;
        let mut vel = vec![0.0f32; self.weights.nnz()];
        for (old, &new) in old_to_new.iter().enumerate() {
            vel[new] = self.velocity[old];
        }
        self.velocity = vel.into();
        debug_assert_eq!(self.weights.nnz(), old_to_new.len() + n_add);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> SparseLayer {
        let mut rng = Rng::new(1);
        SparseLayer::erdos_renyi(
            20,
            10,
            3.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        )
    }

    #[test]
    fn construction_invariants() {
        let l = layer();
        l.weights.validate().unwrap();
        assert_eq!(l.velocity.len(), l.weights.nnz());
        assert_eq!(l.bias.len(), 10);
        assert!(l.param_count() >= l.weights.nnz() + 10);
    }

    #[test]
    fn retain_keeps_velocity_aligned() {
        let mut l = layer();
        for (i, v) in l.velocity.iter_mut().enumerate() {
            *v = i as f32;
        }
        let vals = l.weights.values.clone();
        let removed = l.retain_entries(|k| vals[k] > 0.0);
        assert!(removed > 0);
        assert_eq!(l.velocity.len(), l.weights.nnz());
        // the surviving velocities must still be integers < original nnz
        for &v in &l.velocity {
            assert_eq!(v.fract(), 0.0);
        }
    }

    #[test]
    fn insert_preserves_velocity_of_existing() {
        let mut l = layer();
        for (i, v) in l.velocity.iter_mut().enumerate() {
            *v = (i + 1) as f32;
        }
        // find an empty slot
        let mut empty = None;
        'outer: for i in 0..l.n_in() {
            for j in 0..l.n_out() as u32 {
                if l.weights.find(i, j).is_none() {
                    empty = Some((i as u32, j));
                    break 'outer;
                }
            }
        }
        let (i, j) = empty.unwrap();
        let old_sum: f32 = l.velocity.iter().sum();
        l.insert_entries(vec![(i, j, 0.123)]).unwrap();
        assert_eq!(l.weights.get(i as usize, j), 0.123);
        let new_sum: f32 = l.velocity.iter().sum();
        assert_eq!(old_sum, new_sum); // inserted entry has zero velocity
    }

    #[test]
    fn swap_storage_exchanges_arrays_and_keeps_alignment() {
        let mut l = layer();
        let (mut rp, mut ci, mut va) = (
            l.weights.row_ptr.to_vec(),
            l.weights.col_idx.to_vec(),
            l.weights.values.to_vec(),
        );
        for v in va.iter_mut() {
            *v += 1.0;
        }
        let mut vel = vec![2.5f32; va.len()];
        let old_values = l.weights.values.clone();
        l.swap_storage(&mut rp, &mut ci, &mut va, &mut vel);
        l.weights.validate().unwrap();
        assert_eq!(l.velocity, vec![2.5f32; l.weights.nnz()]);
        // the buffers now hold the layer's previous arrays
        assert_eq!(va, old_values);
        assert_eq!(vel.len(), old_values.len());
    }

    #[test]
    fn forward_into_matches_manual_bias_plus_spmm() {
        let mut l = layer();
        for (j, b) in l.bias.iter_mut().enumerate() {
            *b = j as f32 * 0.1;
        }
        let batch = 3;
        let x: Vec<f32> = (0..batch * l.n_in()).map(|i| (i % 5) as f32 - 2.0).collect();
        let mut pre = vec![7.0f32; batch * l.n_out()]; // stale garbage
        l.forward_into(&x, batch, &mut pre, Exec::sequential());
        let mut oracle = vec![0.0f32; batch * l.n_out()];
        for b in 0..batch {
            oracle[b * l.n_out()..(b + 1) * l.n_out()].copy_from_slice(&l.bias);
        }
        ops::spmm_forward(&x, batch, &l.weights, &mut oracle);
        assert_eq!(pre, oracle);
    }

    #[test]
    fn grads_into_zeroes_buffers_first() {
        let l = layer();
        let batch = 2;
        let x = vec![0.0f32; batch * l.n_in()];
        let dz = vec![0.0f32; batch * l.n_out()];
        let mut gw = vec![3.0f32; l.weights.nnz()];
        let mut gb = vec![3.0f32; l.n_out()];
        l.grads_into(&x, &dz, batch, &mut gw, &mut gb, Exec::sequential());
        assert!(gw.iter().all(|&v| v == 0.0));
        assert!(gb.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn backward_into_matches_two_kernel_oracle() {
        let l = layer();
        let batch = 11; // full block + ragged tail
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..batch * l.n_in())
            .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() })
            .collect();
        let dz: Vec<f32> = (0..batch * l.n_out()).map(|_| rng.normal()).collect();
        // oracle: two-kernel pair
        let mut dx_o = vec![0.0f32; batch * l.n_in()];
        l.grad_input_into(&dz, batch, &mut dx_o, Exec::sequential());
        let mut gw_o = vec![0.0f32; l.weights.nnz()];
        let mut gb_o = vec![0.0f32; l.n_out()];
        l.grads_into(&x, &dz, batch, &mut gw_o, &mut gb_o, Exec::sequential());
        let pool = crate::sparse::WorkerPool::new(4);
        for (label, exec) in [
            ("scoped-1", Exec::scoped(1)),
            ("scoped-4", Exec::scoped(4)),
            ("pooled-4", Exec::pooled(&pool)),
        ] {
            let mut dx = vec![f32::NAN; batch * l.n_in()];
            let mut gw = vec![7.0f32; l.weights.nnz()]; // stale: must be zeroed
            let mut gb = vec![7.0f32; l.n_out()];
            l.backward_into(&x, &dz, batch, Some(&mut dx), &mut gw, &mut gb, exec);
            assert_eq!(dx, dx_o, "{label}");
            assert_eq!(gw, gw_o, "{label}");
            assert_eq!(gb, gb_o, "{label}");
            // dx = None: weight/bias grads only (layer-0 path)
            let mut gw2 = vec![7.0f32; l.weights.nnz()];
            let mut gb2 = vec![7.0f32; l.n_out()];
            l.backward_into(&x, &dz, batch, None, &mut gw2, &mut gb2, exec);
            assert_eq!(gw2, gw_o, "{label}");
            assert_eq!(gb2, gb_o, "{label}");
        }
    }

    #[test]
    fn gated_update_matches_dense_update_bit_for_bit() {
        let opt = MomentumSgd {
            momentum: 0.9,
            weight_decay: 0.0,
        };
        // identical twins (same construction seed)
        let mut dense = layer();
        let mut gated = layer();
        let mut rng = Rng::new(42);
        let mut live = Vec::new();
        for _ in 0..6 {
            // gradients confined to a few input rows, as a sparse batch
            // would produce; everything else is exactly zero
            let mut gw = vec![0.0f32; dense.weights.nnz()];
            for &r in &[0usize, 3, 17] {
                let (s, e) = (dense.weights.row_ptr[r], dense.weights.row_ptr[r + 1]);
                for g in &mut gw[s..e] {
                    *g = rng.normal();
                }
            }
            let gb: Vec<f32> = (0..dense.n_out()).map(|_| rng.normal()).collect();
            dense.apply_update(&opt, &gw, &gb, 0.05);
            gated.apply_update_gated(&opt, &gw, &gb, 0.05, &mut live);
        }
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dense.weights.values), bits(&gated.weights.values));
        assert_eq!(bits(&dense.velocity), bits(&gated.velocity));
        assert_eq!(bits(&dense.bias), bits(&gated.bias));
        assert_eq!(bits(&dense.bias_velocity), bits(&gated.bias_velocity));
        // rows that never saw a gradient were retired from the bitmap
        // after the first (all-live) pass proved their velocity zero
        let live_rows = (0..dense.weights.n_rows)
            .filter(|&r| (live[r >> 6] >> (r & 63)) & 1 != 0)
            .count();
        assert!(
            (1..=3).contains(&live_rows),
            "expected only gradient-active rows live, got {live_rows}"
        );
    }

    #[test]
    fn gated_update_with_weight_decay_falls_back_to_dense() {
        let opt = MomentumSgd::default(); // weight_decay != 0
        let mut dense = layer();
        let mut gated = layer();
        let mut rng = Rng::new(43);
        let mut live = Vec::new();
        for _ in 0..3 {
            let gw: Vec<f32> = (0..dense.weights.nnz())
                .map(|i| if i % 4 == 0 { rng.normal() } else { 0.0 })
                .collect();
            let gb: Vec<f32> = (0..dense.n_out()).map(|_| rng.normal()).collect();
            dense.apply_update(&opt, &gw, &gb, 0.05);
            gated.apply_update_gated(&opt, &gw, &gb, 0.05, &mut live);
        }
        assert!(live.is_empty(), "dense fallback must not touch the bitmap");
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dense.weights.values), bits(&gated.weights.values));
        assert_eq!(bits(&dense.velocity), bits(&gated.velocity));
    }

    #[test]
    fn srelu_counts_in_params() {
        let mut l = layer();
        let base = l.param_count();
        l.srelu = Some(SRelu::new(10));
        assert_eq!(l.param_count(), base + 40);
    }
}
