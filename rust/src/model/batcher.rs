//! Mini-batch iteration with per-epoch shuffling.
//!
//! Workers own disjoint shards of the training set (data parallelism);
//! each worker re-shuffles its shard between local epochs, exactly as in
//! Algorithm 1 ("Each worker shuffles its data partition after each
//! local epoch").

use crate::util::Rng;

/// Indexes a dataset into shuffled mini-batches; gathers rows into a
/// reused contiguous buffer.
#[derive(Debug)]
pub struct Batcher {
    order: Vec<usize>,
    batch: usize,
    n_features: usize,
    cursor: usize,
    xbuf: Vec<f32>,
    ybuf: Vec<u32>,
}

impl Batcher {
    /// Batcher over `n` samples of `n_features` each.
    pub fn new(n: usize, n_features: usize, batch: usize) -> Self {
        assert!(batch > 0);
        Batcher {
            order: (0..n).collect(),
            batch,
            n_features,
            cursor: 0,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }

    /// Restrict to a shard: samples `[lo, hi)` of the dataset (used by
    /// parallel workers).
    pub fn shard(n: usize, n_features: usize, batch: usize, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= n);
        Batcher {
            order: (lo..hi).collect(),
            batch,
            n_features,
            cursor: 0,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }

    /// Samples in this batcher's (shard of the) dataset.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// True when no samples.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Batches per epoch (ceil).
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len().div_ceil(self.batch)
    }

    /// Shuffle and rewind (start of epoch).
    pub fn reset(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next mini-batch gathered from `x`/`y`, or None at epoch end.
    /// Returned slices are valid until the next call.
    pub fn next_batch<'a>(
        &'a mut self,
        x: &[f32],
        y: &[u32],
    ) -> Option<(&'a [f32], &'a [u32])> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.batch).min(self.order.len());
        let idxs = &self.order[self.cursor..end];
        let nf = self.n_features;
        self.xbuf.clear();
        self.xbuf.reserve(idxs.len() * nf);
        self.ybuf.clear();
        for &i in idxs {
            self.xbuf.extend_from_slice(&x[i * nf..(i + 1) * nf]);
            self.ybuf.push(y[i]);
        }
        self.cursor = end;
        Some((&self.xbuf, &self.ybuf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_sample_once() {
        let n = 23;
        let x: Vec<f32> = (0..n * 2).map(|v| v as f32).collect();
        let y: Vec<u32> = (0..n as u32).collect();
        let mut b = Batcher::new(n, 2, 5);
        b.reset(&mut Rng::new(1));
        let mut seen = Vec::new();
        while let Some((_, ys)) = b.next_batch(&x, &y) {
            seen.extend_from_slice(ys);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u32).collect::<Vec<_>>());
    }

    #[test]
    fn gathers_matching_rows() {
        let x = vec![10.0, 11.0, 20.0, 21.0, 30.0, 31.0];
        let y = vec![1u32, 2, 3];
        let mut b = Batcher::new(3, 2, 2);
        b.reset(&mut Rng::new(2));
        while let Some((xs, ys)) = b.next_batch(&x, &y) {
            for (k, &label) in ys.iter().enumerate() {
                assert_eq!(xs[k * 2], label as f32 * 10.0);
                assert_eq!(xs[k * 2 + 1], label as f32 * 10.0 + 1.0);
            }
        }
    }

    #[test]
    fn shard_restricts_indices() {
        let mut b = Batcher::shard(10, 1, 3, 4, 8);
        assert_eq!(b.len(), 4);
        let x: Vec<f32> = (0..10).map(|v| v as f32).collect();
        let y: Vec<u32> = (0..10).collect();
        b.reset(&mut Rng::new(3));
        let mut seen = Vec::new();
        while let Some((_, ys)) = b.next_batch(&x, &y) {
            seen.extend_from_slice(ys);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![4, 5, 6, 7]);
    }

    #[test]
    fn batches_per_epoch_rounds_up() {
        assert_eq!(Batcher::new(10, 1, 3).batches_per_epoch(), 4);
        assert_eq!(Batcher::new(9, 1, 3).batches_per_epoch(), 3);
    }
}
