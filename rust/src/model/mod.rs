//! The truly-sparse MLP model: layers, forward/backward, batching and
//! sparse checkpoints.

pub mod batcher;
pub mod checkpoint;
pub mod layer;
pub mod mlp;

pub use batcher::Batcher;
pub use layer::SparseLayer;
pub use mlp::{SparseMlp, StepStats, Workspace};
