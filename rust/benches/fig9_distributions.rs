//! Figs. 9–18 (supplementary) — activation / pre-activation distributions
//! per hidden layer for ReLU, All-ReLU and SReLU on the CIFAR10-like
//! dataset, plus the learned SReLU slope distributions.
//!
//! Emits results/fig9_18_distributions.csv: histograms (layer, kind,
//! bucket, count) for post-training models — the evidence behind the
//! "from SReLU to All-ReLU" design narrative (§5.1).

use tsnn::bench::{env_usize, paper_scale, write_artifact, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::nn::{Activation, SRelu};
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn histogram(values: &[f32], buckets: usize, lo: f32, hi: f32) -> Vec<usize> {
    let mut h = vec![0usize; buckets];
    let w = (hi - lo) / buckets as f32;
    for &v in values {
        let b = (((v - lo) / w) as isize).clamp(0, buckets as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 1000 } else { 8 });
    let spec = if paper {
        DatasetSpec::paper("cifar")
    } else {
        DatasetSpec::small("cifar")
    };
    let data = tsnn::data::generate(&spec, &mut Rng::new(1)).expect("dataset");

    let mut csv = String::from("kind,layer,bucket_lo,bucket_hi,count\n");
    let mut table = Table::new(
        "Figs. 9-18 — per-layer pre-activation stats (cifar-like)",
        &["activation", "layer", "mean", "std", "frac<0"],
    );
    let (lo, hi, buckets) = (-5.0f32, 5.0f32, 50usize);

    for (act, label, srelu) in [
        (Activation::Relu, "relu", false),
        (Activation::AllRelu { alpha: 0.75 }, "allrelu", false),
        (Activation::Relu, "srelu", true),
    ] {
        let mut cfg = if paper {
            TrainConfig::paper_preset("cifar")
        } else {
            TrainConfig::small_preset("cifar")
        };
        cfg.epochs = epochs;
        cfg.activation = act;
        let mut r = train_sequential(&cfg, &data, &mut Rng::new(42)).expect("train");
        if srelu {
            // retrofit trainable SReLU on hidden layers and fine-tune
            for l in 0..r.model.layers.len() - 1 {
                let n = r.model.layers[l].n_out();
                r.model.layers[l].srelu = Some(SRelu::new(n));
            }
            let mut ws = r.model.alloc_workspace(cfg.batch);
            let opt = cfg.optimizer;
            let mut rng = Rng::new(7);
            let mut batcher = Batcher::new(data.n_train(), data.n_features, cfg.batch);
            for _ in 0..(epochs / 5).max(1) {
                batcher.reset(&mut rng);
                while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
                    r.model.train_step(x, y, &opt, 0.01, None, &mut ws, &mut rng);
                }
            }
        }

        // forward a probe batch, record pre-activation stats per layer
        let probe = 512.min(data.n_train());
        let mut ws = r.model.alloc_workspace(probe);
        r.model
            .forward(&data.x_train[..probe * data.n_features], probe, &mut ws, None);
        for l in 0..r.model.layers.len() - 1 {
            let pre = &ws.pre[l];
            let mean: f64 = pre.iter().map(|&v| v as f64).sum::<f64>() / pre.len() as f64;
            let var: f64 = pre.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>()
                / pre.len() as f64;
            let neg = pre.iter().filter(|&&v| v < 0.0).count() as f64 / pre.len() as f64;
            table.row(vec![
                label.into(),
                format!("{}", l + 1),
                format!("{mean:.3}"),
                format!("{:.3}", var.sqrt()),
                format!("{neg:.3}"),
            ]);
            for (b, count) in histogram(pre, buckets, lo, hi).into_iter().enumerate() {
                let blo = lo + (hi - lo) * b as f32 / buckets as f32;
                let bhi = lo + (hi - lo) * (b + 1) as f32 / buckets as f32;
                csv.push_str(&format!("{label}_pre,{},{blo},{bhi},{count}\n", l + 1));
            }
        }
        // SReLU learned slopes (Figs. 15-17)
        if srelu {
            for (l, layer) in r.model.layers.iter().enumerate() {
                if let Some(s) = &layer.srelu {
                    for (b, count) in histogram(&s.al, 20, -1.0, 1.0).into_iter().enumerate() {
                        let blo = -1.0 + 2.0 * b as f32 / 20.0;
                        csv.push_str(&format!(
                            "srelu_left_slope,{},{blo},{},{count}\n",
                            l + 1,
                            blo + 0.1
                        ));
                    }
                }
            }
        }
    }

    table.emit("fig9_18_distributions.csv");
    let _ = write_artifact("fig9_18_histograms.csv", &csv);
    println!("paper reference (Figs. 9-18): All-ReLU's alternating negative");
    println!("slope mirrors the sign-alternating left slopes SReLU learns.");
}
