//! Table 4 — extreme-scale sparse MLP timings.
//!
//! Sweeps architectures over the 65536-feature "big artificial dataset",
//! reporting per-epoch wall times of the four phases the paper tables:
//! weight initialisation, training, testing and weight evolution, plus
//! neuron/parameter counts and the dense-equivalent memory that would be
//! required (demonstrating why the dense model OOMs).
//!
//! Default sweep is scaled to a 1-core/35 GB host (0.1M–2M neurons);
//! TSNN_SCALE=paper attempts the paper's 1M–50M neuron ladder.
//! Also covers the §2.4 text experiment (leukemia-like, ε=1) with
//! TSNN_LEUKEMIA=1.

use tsnn::bench::{env_usize, fmt_duration, paper_scale, Table};
use tsnn::config::DatasetSpec;
use tsnn::nn::MomentumSgd;
use tsnn::prelude::*;
use tsnn::set::{evolve_model, EvolutionConfig};
use tsnn::util::Timer;

struct Row {
    arch: String,
    epsilon: f64,
    sizes: Vec<usize>,
}

fn main() {
    let paper = paper_scale();
    let batch = env_usize("TSNN_BATCH", 128);
    // paper: 65536-0.5M-0.5M-2 (ε=10) ... 65536-5Mx10-2 (ε=1)
    let rows: Vec<Row> = if paper {
        vec![
            Row { arch: "65536-0.5M-0.5M-2".into(), epsilon: 10.0, sizes: vec![65536, 500_000, 500_000, 2] },
            Row { arch: "65536-2.5M-2.5M-2".into(), epsilon: 5.0, sizes: vec![65536, 2_500_000, 2_500_000, 2] },
            Row { arch: "65536-5M-5M-2".into(), epsilon: 5.0, sizes: vec![65536, 5_000_000, 5_000_000, 2] },
            Row { arch: "65536-5Mx4-2".into(), epsilon: 1.0, sizes: vec![65536, 5_000_000, 5_000_000, 5_000_000, 5_000_000, 2] },
        ]
    } else {
        vec![
            Row { arch: "65536-50k-50k-2".into(), epsilon: 10.0, sizes: vec![65536, 50_000, 50_000, 2] },
            Row { arch: "65536-100k-100k-2".into(), epsilon: 5.0, sizes: vec![65536, 100_000, 100_000, 2] },
            Row { arch: "65536-250k-250k-2".into(), epsilon: 5.0, sizes: vec![65536, 250_000, 250_000, 2] },
            Row { arch: "65536-250kx4-2".into(), epsilon: 1.0, sizes: vec![65536, 250_000, 250_000, 250_000, 250_000, 2] },
        ]
    };

    // dataset: fixed small sample count — Table 4 times phases, not accuracy
    let spec = DatasetSpec {
        name: "extreme".into(),
        generator: "extreme".into(),
        n_features: 65_536,
        n_classes: 2,
        n_train: env_usize("TSNN_TRAIN", 128),
        n_test: env_usize("TSNN_TEST", 128),
    };
    println!("generating the big artificial dataset ({} features) ...", spec.n_features);
    let mut rng = Rng::new(3);
    let data = tsnn::data::generate(&spec, &mut rng).expect("dataset");

    let mut table = Table::new(
        "Table 4 — extreme-scale per-epoch phase timings",
        &["architecture", "eps", "neurons", "params", "init", "train/ep", "test", "evolution",
          "sparse MiB", "dense GiB (OOM?)"],
    );

    for row in &rows {
        let mut rng = Rng::new(7);
        let t = Timer::start();
        let model = SparseMlp::new(
            &row.sizes,
            row.epsilon,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        );
        let mut model = match model {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skipping {}: {e}", row.arch);
                continue;
            }
        };
        let init_s = t.secs();

        let mut ws = model.alloc_workspace(batch);
        let opt = MomentumSgd::default();
        let mut batcher = Batcher::new(data.n_train(), data.n_features, batch);
        batcher.reset(&mut rng);
        let t = Timer::start();
        while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
            model.train_step(x, y, &opt, 0.01, None, &mut ws, &mut rng);
        }
        let train_s = t.secs();

        let t = Timer::start();
        let (_, _acc) = model.evaluate(&data.x_test, &data.y_test, batch, &mut ws);
        let test_s = t.secs();

        let t = Timer::start();
        evolve_model(&mut model, &EvolutionConfig::default(), &mut rng).expect("evolve");
        let evo_s = t.secs();

        let dense_w: f64 = row.sizes.windows(2).map(|w| w[0] as f64 * w[1] as f64).sum();
        let dense_gib = dense_w * 4.0 / 1073741824.0;
        table.row(vec![
            row.arch.clone(),
            format!("{}", row.epsilon),
            format!("{:.2}M", model.neuron_count() as f64 / 1e6),
            format!("{:.1}M", model.weight_count() as f64 / 1e6),
            fmt_duration(init_s),
            fmt_duration(train_s),
            fmt_duration(test_s),
            fmt_duration(evo_s),
            format!("{:.0}", model.memory_bytes() as f64 / 1048576.0),
            format!("{dense_gib:.0}{}", if dense_gib > 30.0 { " (OOM)" } else { "" }),
        ]);
    }

    // §2.4 text experiment: leukemia-like at ε=1, sequential epoch timing
    if std::env::var("TSNN_LEUKEMIA").is_ok() {
        let spec = DatasetSpec {
            name: "leukemia-extreme".into(),
            generator: "leukemia".into(),
            n_features: 54_675,
            n_classes: 18,
            n_train: 512,
            n_test: 128,
        };
        let data = tsnn::data::generate(&spec, &mut Rng::new(5)).expect("leukemia");
        let sizes = vec![54_675, 5_000_000, 5_000_000, 18];
        let mut rng = Rng::new(9);
        let t = Timer::start();
        let mut model = SparseMlp::new(&sizes, 1.0, Activation::AllRelu { alpha: 0.75 },
                                       &WeightInit::Normal(0.05), &mut rng).expect("model");
        let init_s = t.secs();
        let mut ws = model.alloc_workspace(32);
        let opt = MomentumSgd::default();
        let mut batcher = Batcher::new(data.n_train(), data.n_features, 32);
        batcher.reset(&mut rng);
        let t = Timer::start();
        while let Some((x, y)) = batcher.next_batch(&data.x_train, &data.y_train) {
            model.train_step(x, y, &opt, 0.005, None, &mut ws, &mut rng);
        }
        println!(
            "§2.4 leukemia 10M-neuron run: init {} train/epoch {} (params {:.1}M)",
            fmt_duration(init_s),
            fmt_duration(t.secs()),
            model.weight_count() as f64 / 1e6
        );
    }

    table.emit("table4_extreme.csv");
    println!("paper reference (Table 4): init/train/test/evolution scale ~linearly");
    println!("with parameters; evolution adds little overhead; dense OOMs first.");
}
