//! §Perf — runtime-dispatched SIMD microkernels vs the scalar baseline
//! (DESIGN.md §11). Every timed pair is parity-asserted first: the SIMD
//! microkernels are bit-exact by construction (no FMA, scalar-identical
//! accumulation order), so speedup never trades off against §4's
//! invariance guarantee. Emits a machine-readable `BENCH_6.json` at the
//! repository root.
//!
//! Four measurement families:
//!   * `isa_kernel` — all four CSR kernels on sequential `Exec`, scalar
//!     vs every vector ISA the host supports (`Isa::available()`).
//!     Acceptance: best vector-ISA speedup ≥ 1.3× over scalar.
//!   * `isa_dense` — the serving dense-fallback kernel through a real
//!     `ServeModel` layer, forced per-ISA via `ServeWorkspace::force_isa`.
//!   * `row_schedule` — grad_weights + fused on a straggler-row matrix
//!     (§11.4) under a pooled `Exec`: `Contiguous` vs `Adaptive`
//!     length-sorted LPT scheduling.
//!   * `e2e` — forward + fused-backward step, scalar vs the detected
//!     best ISA.
//!
//! Knobs: TSNN_ITERS (default 20), TSNN_BATCH (default 64),
//! TSNN_REPO_ROOT. `TSNN_ISA` is deliberately ignored here: the bench
//! sweeps every supported ISA explicitly.

use tsnn::bench::{env_usize, host_info, time_it, write_repo_root_json, Table};
use tsnn::model::SparseLayer;
use tsnn::prelude::*;
use tsnn::serve::{LayerFormat, LayoutOptions, ServeModel, ServeWorkspace};
use tsnn::sparse::{detected_isa, erdos_renyi, ops, CsrMatrix, Exec, Isa, WorkerPool};
use tsnn::util::json::{obj, Json};

fn random_vec(rng: &mut Rng, n: usize, zero_frac: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.bernoulli(zero_frac) {
                0.0
            } else {
                rng.normal()
            }
        })
        .collect()
}

/// Single dense-enough layer so the serving layout picks the dense
/// fallback — the serve-path kernel the ISA table widens the most.
fn dense_model(n_in: usize, n_out: usize, seed: u64) -> SparseMlp {
    let mut rng = Rng::new(seed);
    let weights = erdos_renyi(n_in, n_out, 0.6, &mut rng, &WeightInit::Normal(0.3));
    let layer = SparseLayer {
        bias: (0..n_out).map(|_| rng.normal() * 0.1).collect(),
        velocity: vec![0.0; weights.nnz()].into(),
        bias_velocity: vec![0.0; n_out],
        weights,
        activation: Activation::Linear,
        srelu: None,
    };
    SparseMlp {
        sizes: vec![n_in, n_out],
        layers: vec![layer],
    }
}

/// Straggler-row CSR matrix (§11.4): row 3 owns every column, every
/// other row carries `tail_nnz` scattered entries.
fn skewed_matrix(n_rows: u32, n_cols: u32, tail_nnz: u32) -> CsrMatrix {
    let mut coo: Vec<(u32, u32, f32)> = Vec::new();
    for j in 0..n_cols {
        coo.push((3, j, 0.01 * (j % 97) as f32 - 0.5));
    }
    for r in 0..n_rows {
        if r == 3 {
            continue;
        }
        for t in 0..tail_nnz {
            coo.push((r, (r * 37 + t * 131) % n_cols, 0.05 * (r % 13) as f32 - 0.3));
        }
    }
    CsrMatrix::from_coo(n_rows as usize, n_cols as usize, coo).unwrap()
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 20);
    let batch = env_usize("TSNN_BATCH", 64);
    let cores = ops::available_threads();
    let available = Isa::available();
    let mut rows: Vec<Json> = Vec::new();
    let mut best_speedup: f64 = 0.0;

    println!(
        "host: {cores} cores; detected ISA: {}; available: {}\n",
        detected_isa().name(),
        available.iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
    );

    // ---- 1. per-kernel ISA sweep on sequential Exec ----
    let mut kern = Table::new(
        "§Perf — CSR kernels, scalar microkernel vs each vector ISA (sequential Exec, \
         parity-asserted)",
        &["kernel", "shape", "density", "isa", "scalar µs", "isa µs", "speedup"],
    );
    let shapes = [(1024usize, 1024usize, 0.05f64), (1024, 1024, 0.2), (4096, 256, 0.1)];
    for &(n_in, n_out, density) in &shapes {
        let mut rng = Rng::new(17);
        let w = erdos_renyi(n_in, n_out, density, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let shape = format!("{n_in}x{n_out}");
        let x = random_vec(&mut rng, batch * n_in, 0.3);
        let dz = random_vec(&mut rng, batch * n_out, 0.0);
        let mut out = vec![0.0f32; batch * n_out];
        let mut dx = vec![0.0f32; batch * n_in];
        let mut dw = vec![0.0f32; nnz];

        let scalar = Exec::sequential().with_isa(Isa::Scalar);
        let (fwd_scalar, _) = time_it(2, iters, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_forward_exec(&x, batch, &w, &mut out, scalar);
        });
        let fwd_ref = out.clone();
        let (din_scalar, _) = time_it(2, iters, || {
            ops::spmm_grad_input_exec(&dz, batch, &w, &mut dx, scalar);
        });
        let din_ref = dx.clone();
        let (dwt_scalar, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, scalar);
        });
        let dwt_ref = dw.clone();
        let (fused_scalar, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, scalar);
        });

        for &isa in &available {
            if isa == Isa::Scalar {
                continue;
            }
            let exec = Exec::sequential().with_isa(isa);
            let (fwd_isa, _) = time_it(2, iters, || {
                out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward_exec(&x, batch, &w, &mut out, exec);
            });
            assert_eq!(out, fwd_ref, "forward parity {shape} {}", isa.name());
            let (din_isa, _) = time_it(2, iters, || {
                ops::spmm_grad_input_exec(&dz, batch, &w, &mut dx, exec);
            });
            assert_eq!(dx, din_ref, "grad_input parity {shape} {}", isa.name());
            let (dwt_isa, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, exec);
            });
            assert_eq!(dw, dwt_ref, "grad_weights parity {shape} {}", isa.name());
            let (fused_isa, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
            });
            assert_eq!(dx, din_ref, "fused dx parity {shape} {}", isa.name());
            assert_eq!(dw, dwt_ref, "fused dw parity {shape} {}", isa.name());

            for (kernel, scalar_secs, isa_secs) in [
                ("spmm_forward", fwd_scalar, fwd_isa),
                ("spmm_grad_input", din_scalar, din_isa),
                ("spmm_grad_weights", dwt_scalar, dwt_isa),
                ("backward_fused", fused_scalar, fused_isa),
            ] {
                let speedup = scalar_secs / isa_secs.max(1e-12);
                best_speedup = best_speedup.max(speedup);
                kern.row(vec![
                    kernel.into(),
                    shape.clone(),
                    format!("{density}"),
                    isa.name().into(),
                    format!("{:.2}", scalar_secs * 1e6),
                    format!("{:.2}", isa_secs * 1e6),
                    format!("{speedup:.2}x"),
                ]);
                rows.push(obj(vec![
                    ("op", "isa_kernel".into()),
                    ("kernel", kernel.into()),
                    ("n_in", n_in.into()),
                    ("n_out", n_out.into()),
                    ("nnz", nnz.into()),
                    ("batch", batch.into()),
                    ("isa", isa.name().into()),
                    ("scalar_ns", (scalar_secs * 1e9).into()),
                    ("isa_ns", (isa_secs * 1e9).into()),
                    ("speedup", speedup.into()),
                ]));
            }
        }
    }
    kern.emit("perf_simd_kernels.csv");

    // ---- 2. serving dense-fallback kernel, forced per ISA ----
    let mut dense = Table::new(
        "§Perf — serving dense-fallback kernel, scalar vs each vector ISA \
         (ServeWorkspace::force_isa, parity-asserted)",
        &["shape", "isa", "scalar µs", "isa µs", "speedup"],
    );
    {
        let (n_in, n_out) = (512usize, 512usize);
        let mlp = dense_model(n_in, n_out, 7);
        let serve = ServeModel::from_mlp(&mlp, &LayoutOptions::default());
        assert_eq!(
            serve.layers[0].format(),
            LayerFormat::Dense,
            "dense bench layer must take the dense-fallback format"
        );
        let mut rng = Rng::new(23);
        let x = random_vec(&mut rng, batch * n_in, 0.3);
        let mut ws = ServeWorkspace::with_threads(1);
        ws.force_isa = Some(Isa::Scalar);
        let scalar_ref = serve.forward(&x, batch, &mut ws).to_vec();
        let (scalar_secs, _) = time_it(2, iters, || {
            std::hint::black_box(serve.forward(&x, batch, &mut ws));
        });
        for &isa in &available {
            if isa == Isa::Scalar {
                continue;
            }
            ws.force_isa = Some(isa);
            assert_eq!(
                scalar_ref,
                serve.forward(&x, batch, &mut ws),
                "dense serving parity {}",
                isa.name()
            );
            let (isa_secs, _) = time_it(2, iters, || {
                std::hint::black_box(serve.forward(&x, batch, &mut ws));
            });
            let speedup = scalar_secs / isa_secs.max(1e-12);
            best_speedup = best_speedup.max(speedup);
            dense.row(vec![
                format!("{n_in}x{n_out}"),
                isa.name().into(),
                format!("{:.2}", scalar_secs * 1e6),
                format!("{:.2}", isa_secs * 1e6),
                format!("{speedup:.2}x"),
            ]);
            rows.push(obj(vec![
                ("op", "isa_dense".into()),
                ("n_in", n_in.into()),
                ("n_out", n_out.into()),
                ("batch", batch.into()),
                ("isa", isa.name().into()),
                ("scalar_ns", (scalar_secs * 1e9).into()),
                ("isa_ns", (isa_secs * 1e9).into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    dense.emit("perf_simd_dense.csv");

    // ---- 3. row scheduling on a straggler-row matrix (§11.4) ----
    let mut sched = Table::new(
        "§Perf — straggler-row matrix, pooled Exec: contiguous shards vs \
         length-sorted LPT scheduling (parity-asserted)",
        &["kernel", "contiguous µs", "adaptive µs", "speedup"],
    );
    {
        let w = skewed_matrix(256, 4096, 16);
        let nnz = w.nnz();
        assert!(batch * nnz >= ops::POOL_MIN_WORK, "must cross the warm crossover");
        let mut rng = Rng::new(41);
        let x = random_vec(&mut rng, batch * 256, 0.3);
        let dz = random_vec(&mut rng, batch * 4096, 0.0);
        let mut dx = vec![0.0f32; batch * 256];
        let mut dw = vec![0.0f32; nnz];
        let mut dwt_ref = vec![0.0f32; nnz];
        let mut din_ref = vec![0.0f32; batch * 256];
        ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dwt_ref);
        ops::spmm_grad_input(&dz, batch, &w, &mut din_ref);
        let threads = 4usize.min(cores.max(2));
        let pool = WorkerPool::new(threads);
        let exec = Exec::pooled(&pool);
        let mut timings: Vec<(&str, f64, f64)> = Vec::new();
        for (policy_name, policy) in [
            ("contiguous", ops::RowSchedulePolicy::Contiguous),
            ("adaptive", ops::RowSchedulePolicy::Adaptive),
        ] {
            ops::set_row_schedule_policy(policy);
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, exec);
            assert_eq!(dw, dwt_ref, "grad_weights parity ({policy_name})");
            let (dwt_secs, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, exec);
            });
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
            assert_eq!(dx, din_ref, "fused dx parity ({policy_name})");
            assert_eq!(dw, dwt_ref, "fused dw parity ({policy_name})");
            let (fused_secs, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
            });
            timings.push((policy_name, dwt_secs, fused_secs));
        }
        ops::set_row_schedule_policy(ops::RowSchedulePolicy::Adaptive);
        let (contig, adaptive) = (timings[0], timings[1]);
        for (kernel, c_secs, a_secs) in [
            ("spmm_grad_weights", contig.1, adaptive.1),
            ("backward_fused", contig.2, adaptive.2),
        ] {
            let speedup = c_secs / a_secs.max(1e-12);
            sched.row(vec![
                kernel.into(),
                format!("{:.2}", c_secs * 1e6),
                format!("{:.2}", a_secs * 1e6),
                format!("{speedup:.2}x"),
            ]);
            rows.push(obj(vec![
                ("op", "row_schedule".into()),
                ("kernel", kernel.into()),
                ("nnz", nnz.into()),
                ("batch", batch.into()),
                ("threads", threads.into()),
                ("contiguous_ns", (c_secs * 1e9).into()),
                ("adaptive_ns", (a_secs * 1e9).into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    sched.emit("perf_simd_schedule.csv");

    // ---- 4. end-to-end forward + fused-backward step ----
    let mut e2e = Table::new(
        "§Perf — forward + fused-backward training step, scalar vs detected best ISA",
        &["shape", "isa", "scalar µs", "isa µs", "speedup"],
    );
    {
        let mut rng = Rng::new(53);
        let w = erdos_renyi(1024, 1024, 0.1, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let x = random_vec(&mut rng, batch * 1024, 0.3);
        let dz = random_vec(&mut rng, batch * 1024, 0.0);
        let mut out = vec![0.0f32; batch * 1024];
        let mut dx = vec![0.0f32; batch * 1024];
        let mut dw = vec![0.0f32; nnz];
        let best = *available.last().unwrap();
        let scalar = Exec::sequential().with_isa(Isa::Scalar);
        let (scalar_secs, _) = time_it(2, iters, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_forward_exec(&x, batch, &w, &mut out, scalar);
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, scalar);
        });
        let (out_ref, dx_ref, dw_ref) = (out.clone(), dx.clone(), dw.clone());
        let exec = Exec::sequential().with_isa(best);
        let (isa_secs, _) = time_it(2, iters, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_forward_exec(&x, batch, &w, &mut out, exec);
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
        });
        assert_eq!(out, out_ref, "e2e forward parity {}", best.name());
        assert_eq!(dx, dx_ref, "e2e dx parity {}", best.name());
        assert_eq!(dw, dw_ref, "e2e dw parity {}", best.name());
        let speedup = scalar_secs / isa_secs.max(1e-12);
        if best != Isa::Scalar {
            best_speedup = best_speedup.max(speedup);
        }
        e2e.row(vec![
            "1024x1024".into(),
            best.name().into(),
            format!("{:.2}", scalar_secs * 1e6),
            format!("{:.2}", isa_secs * 1e6),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("op", "e2e".into()),
            ("nnz", nnz.into()),
            ("batch", batch.into()),
            ("isa", best.name().into()),
            ("scalar_ns", (scalar_secs * 1e9).into()),
            ("isa_ns", (isa_secs * 1e9).into()),
            ("speedup", speedup.into()),
        ]));
    }
    e2e.emit("perf_simd_e2e.csv");

    let doc = obj(vec![
        ("bench", "perf_simd".into()),
        ("pr", 7usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("host_threads", cores.into()),
        ("iters", iters.into()),
        ("batch", batch.into()),
        ("isa_detected", detected_isa().name().into()),
        (
            "isa_available",
            Json::Arr(available.iter().map(|i| i.name().into()).collect()),
        ),
        (
            "acceptance",
            obj(vec![
                ("simd_vs_scalar_min_speedup", Json::from(1.3f64)),
                (
                    "note",
                    "best vector-ISA speedup over the scalar microkernel across the \
                     isa_kernel/isa_dense rows; parity asserted bit-exact before every timed \
                     pair; on scalar-only hosts there are no vector rows and the gate is \
                     skipped with a note (the scalar fallback is still exercised and \
                     bit-exact on every CI matrix leg)"
                        .into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_6.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_6.json: {e}"),
    }

    if available.len() > 1 {
        println!(
            "acceptance gate: best vector-ISA speedup over scalar = {best_speedup:.2}x \
             (required >= 1.30x on a vector-ISA host)."
        );
    } else {
        println!(
            "acceptance gate: scalar-only host — no vector ISA to compare; the speedup \
             gate applies on AVX2/AVX-512/NEON hosts."
        );
    }
}
