//! §Perf — sparse inference serving (DESIGN.md §10): closed-loop
//! traffic replay against a [`ServeEngine`] sweeping offered QPS to
//! saturation. Emits a machine-readable `BENCH_5.json` at the
//! repository root.
//!
//! Three measurement families:
//!   * `format_crossover` — one 256×256 layer served CSR vs forced
//!     dense across a density grid (bit-parity asserted first), deriving
//!     the measured density knee that motivates the default
//!     `DENSE_CROSSOVER_DENSITY` layout knob.
//!   * `qps_step` — the tentpole protocol: a trained model goes through
//!     the real save→`ServeModel::load` path (formats asserted: the
//!     ε-sparse hidden layers stay CSR, the dense output layer falls
//!     back), then `serve::loadgen::sweep` replays paced traffic at
//!     geometrically growing offered QPS until the engine saturates —
//!     once through a batching front end (`max_batch` 32) and once
//!     batch-1 — recording p50/p95/p99 latency and achieved throughput
//!     per step.
//!   * `peak` — the knee of each sweep. Acceptance: adaptive batching
//!     must buy ≥ 1.5× peak throughput over the batch-1 front end.
//!
//! Knobs: TSNN_REQUESTS (per step, default 400), TSNN_QPS0 (default
//! 250), TSNN_STEPS (default 8), TSNN_ITERS (crossover timing, default
//! 30), TSNN_THREADS (batcher kernel budget, default 0 = all cores),
//! TSNN_REPO_ROOT.

use std::time::Duration;

use tsnn::bench::{env_f64, env_usize, host_info, time_it, write_repo_root_json, Table};
use tsnn::prelude::*;
use tsnn::serve::{sweep, LayerFormat, LayoutOptions, ServeWorkspace, SweepConfig};
use tsnn::sparse::erdos_renyi;
use tsnn::util::json::{obj, Json};

fn random_x(rng: &mut Rng, n: usize, zero_frac: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.bernoulli(zero_frac) {
                0.0
            } else {
                rng.normal()
            }
        })
        .collect()
}

/// Single-layer model with an exact density (the crossover family needs
/// direct control, not the ε scaling of `SparseMlp::new`).
fn single_layer_mlp(n: usize, density: f64, seed: u64) -> SparseMlp {
    let mut rng = Rng::new(seed);
    let weights = erdos_renyi(n, n, density, &mut rng, &WeightInit::Normal(0.3));
    let layer = SparseLayer {
        bias: (0..n).map(|_| rng.normal() * 0.1).collect(),
        velocity: vec![0.0; weights.nnz()].into(),
        bias_velocity: vec![0.0; n],
        weights,
        activation: Activation::Linear,
        srelu: None,
    };
    SparseMlp {
        sizes: vec![n, n],
        layers: vec![layer],
    }
}

/// Training-path logits (sequential oracle for the parity asserts).
fn training_logits(mlp: &SparseMlp, x: &[f32], batch: usize) -> Vec<f32> {
    let mut ws = mlp.alloc_workspace(batch);
    ws.kernel_threads = 1;
    mlp.forward(x, batch, &mut ws, None).to_vec()
}

fn fmt_name(f: LayerFormat) -> &'static str {
    match f {
        LayerFormat::Csr => "csr",
        LayerFormat::Dense => "dense",
    }
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 30);
    let threads = env_usize("TSNN_THREADS", 0);
    let sweep_cfg = SweepConfig {
        start_qps: env_f64("TSNN_QPS0", 250.0),
        growth: 2.0,
        max_steps: env_usize("TSNN_STEPS", 8),
        requests_per_step: env_usize("TSNN_REQUESTS", 400).max(1),
        saturation_ratio: 0.9,
    };
    let mut rows: Vec<Json> = Vec::new();

    // ---- 1. format crossover: CSR vs dense-fallback serving ----
    let mut xover = Table::new(
        "§Perf — serving format crossover (256×256 layer, batch 32): CSR vs dense-fallback",
        &["density", "nnz", "csr µs", "dense µs", "dense/csr", "faster"],
    );
    let mut measured_knee: Option<f64> = None;
    {
        let (n, batch) = (256usize, 32usize);
        let force_csr = LayoutOptions { dense_crossover: 2.0 };
        let force_dense = LayoutOptions { dense_crossover: 0.0 };
        let mut rng = Rng::new(17);
        for &density in &[0.02f64, 0.05, 0.1, 0.2, 0.4, 0.8] {
            let mlp = single_layer_mlp(n, density, 100 + (density * 1000.0) as u64);
            let nnz = mlp.layers[0].weights.nnz();
            let as_csr = ServeModel::from_mlp(&mlp, &force_csr);
            let as_dense = ServeModel::from_mlp(&mlp, &force_dense);
            assert_eq!(as_csr.layers[0].format(), LayerFormat::Csr);
            assert_eq!(as_dense.layers[0].format(), LayerFormat::Dense);
            let x = random_x(&mut rng, batch * n, 0.3);
            // bit-parity of both formats vs the training path, then
            // against each other, before any timing
            let oracle = training_logits(&mlp, &x, batch);
            let mut ws = ServeWorkspace::with_threads(1);
            assert_eq!(oracle, as_csr.forward(&x, batch, &mut ws), "csr parity d={density}");
            assert_eq!(oracle, as_dense.forward(&x, batch, &mut ws), "dense parity d={density}");
            let (csr_secs, _) = time_it(3, iters, || {
                std::hint::black_box(as_csr.forward(&x, batch, &mut ws).len());
            });
            let (dense_secs, _) = time_it(3, iters, || {
                std::hint::black_box(as_dense.forward(&x, batch, &mut ws).len());
            });
            let ratio = dense_secs / csr_secs.max(1e-12);
            if ratio <= 1.0 && measured_knee.is_none() {
                measured_knee = Some(density);
            }
            xover.row(vec![
                format!("{density:.2}"),
                nnz.to_string(),
                format!("{:.2}", csr_secs * 1e6),
                format!("{:.2}", dense_secs * 1e6),
                format!("{ratio:.2}"),
                if ratio <= 1.0 { "dense" } else { "csr" }.into(),
            ]);
            rows.push(obj(vec![
                ("op", "format_crossover".into()),
                ("n", n.into()),
                ("batch", batch.into()),
                ("density", density.into()),
                ("nnz", nnz.into()),
                ("csr_ns", (csr_secs * 1e9).into()),
                ("dense_ns", (dense_secs * 1e9).into()),
                ("dense_vs_csr", ratio.into()),
            ]));
        }
    }
    xover.emit("perf_serving_crossover.csv");
    let knee = measured_knee.unwrap_or(1.0);
    println!(
        "measured dense-fallback knee: density ≈ {knee:.2} (layout default {})\n",
        tsnn::serve::DENSE_CROSSOVER_DENSITY
    );
    rows.push(obj(vec![
        ("op", "crossover_derived".into()),
        ("measured_knee_density", knee.into()),
        ("default_knob", tsnn::serve::DENSE_CROSSOVER_DENSITY.into()),
    ]));

    // ---- 2. the served model: train-shaped, checkpointed, reloaded ----
    // [512 → 1024 → 512 → 10] at ε = 20: hidden layers land at ~6%
    // density (CSR), the 512→10 head crosses the knee (dense fallback).
    let mut rng = Rng::new(23);
    let mlp = SparseMlp::new(
        &[512, 1024, 512, 10],
        20.0,
        Activation::AllRelu { alpha: 0.6 },
        &WeightInit::HeUniform,
        &mut rng,
    )
    .unwrap();
    let dir = std::env::temp_dir().join("tsnn_perf_serving");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("model.tsnn");
    tsnn::model::checkpoint::save(&mlp, &ckpt).unwrap();
    let model = ServeModel::load(&ckpt, &LayoutOptions::default()).unwrap();
    let _ = std::fs::remove_file(&ckpt);

    let mut fmt_table = Table::new(
        "§Perf — served layout (save → ServeModel::load)",
        &["layer", "shape", "density", "nnz", "format", "KiB"],
    );
    for (l, layer) in model.layers.iter().enumerate() {
        fmt_table.row(vec![
            l.to_string(),
            format!("{}x{}", layer.n_in(), layer.n_out()),
            format!("{:.3}", layer.density),
            layer.nnz().to_string(),
            fmt_name(layer.format()).into(),
            format!("{:.1}", layer.memory_bytes() as f64 / 1024.0),
        ]);
    }
    fmt_table.emit("perf_serving_layout.csv");
    let formats: Vec<LayerFormat> = model.layers.iter().map(|l| l.format()).collect();
    assert_eq!(
        formats,
        [LayerFormat::Csr, LayerFormat::Csr, LayerFormat::Dense],
        "ε=20 model must exercise both serving formats"
    );
    // end-to-end parity of the reloaded layout before any load testing
    {
        let x = random_x(&mut rng, 8 * 512, 0.3);
        let oracle = training_logits(&mlp, &x, 8);
        for t in [1usize, threads] {
            let mut ws = ServeWorkspace::with_threads(t);
            assert_eq!(oracle, model.forward(&x, 8, &mut ws), "serving parity t{t}");
        }
    }

    // ---- 3. QPS sweep: batched vs batch-1 front end ----
    let n_feat = model.n_features();
    let features = random_x(&mut rng, 64 * n_feat, 0.3);
    let mut qps_table = Table::new(
        "§Perf — offered-QPS sweep to saturation (closed-loop replay)",
        &["mode", "offered", "achieved", "p50 µs", "p95 µs", "p99 µs", "rejected", "sat"],
    );
    let mut peaks: Vec<(&str, f64)> = Vec::new();
    for (mode, max_batch) in [("batched", 32usize), ("batch1", 1usize)] {
        let cfg = ServeConfig {
            max_batch,
            max_queue: 1024,
            max_wait: Duration::from_millis(2),
            kernel_threads: threads,
            latency_window: sweep_cfg.requests_per_step,
        };
        let mut engine = ServeEngine::new(model.clone(), cfg);
        let reports = sweep(&engine, &features, n_feat, &sweep_cfg);
        engine.shutdown();
        let mut peak = 0.0f64;
        for r in &reports {
            peak = peak.max(r.achieved_qps);
            qps_table.row(vec![
                mode.into(),
                format!("{:.0}", r.offered_qps),
                format!("{:.0}", r.achieved_qps),
                format!("{:.1}", r.latency.p50_ns as f64 / 1e3),
                format!("{:.1}", r.latency.p95_ns as f64 / 1e3),
                format!("{:.1}", r.latency.p99_ns as f64 / 1e3),
                r.rejected.to_string(),
                if r.saturated { "*" } else { "" }.into(),
            ]);
            rows.push(obj(vec![
                ("op", "qps_step".into()),
                ("mode", mode.into()),
                ("threads", threads.into()),
                ("offered_qps", r.offered_qps.into()),
                ("achieved_qps", r.achieved_qps.into()),
                ("completed", (r.completed as usize).into()),
                ("rejected", (r.rejected as usize).into()),
                ("p50_us", (r.latency.p50_ns as f64 / 1e3).into()),
                ("p95_us", (r.latency.p95_ns as f64 / 1e3).into()),
                ("p99_us", (r.latency.p99_ns as f64 / 1e3).into()),
                ("mean_us", (r.latency.mean_ns / 1e3).into()),
                ("saturated", r.saturated.into()),
            ]));
        }
        peaks.push((mode, peak));
        rows.push(obj(vec![
            ("op", "peak".into()),
            ("mode", mode.into()),
            ("peak_qps", peak.into()),
        ]));
    }
    qps_table.emit("perf_serving_qps.csv");

    let batched_peak = peaks.iter().find(|(m, _)| *m == "batched").unwrap().1;
    let batch1_peak = peaks.iter().find(|(m, _)| *m == "batch1").unwrap().1;
    let peak_ratio = batched_peak / batch1_peak.max(1e-9);
    println!(
        "peak throughput: batched {batched_peak:.0} qps vs batch-1 {batch1_peak:.0} qps \
         ({peak_ratio:.2}x)\n"
    );

    let doc = obj(vec![
        ("bench", "perf_serving".into()),
        ("pr", 5usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("threads", threads.into()),
        ("requests_per_step", sweep_cfg.requests_per_step.into()),
        ("start_qps", sweep_cfg.start_qps.into()),
        (
            "model",
            obj(vec![
                (
                    "sizes",
                    Json::Arr(model.sizes.iter().map(|&s| s.into()).collect()),
                ),
                (
                    "formats",
                    Json::Arr(formats.iter().map(|&f| fmt_name(f).into()).collect()),
                ),
                ("serve_bytes", model.memory_bytes().into()),
                ("training_bytes", mlp.memory_bytes().into()),
            ]),
        ),
        ("batched_peak_qps", batched_peak.into()),
        ("batch1_peak_qps", batch1_peak.into()),
        ("peak_ratio", peak_ratio.into()),
        ("measured_knee_density", knee.into()),
        (
            "acceptance",
            obj(vec![
                ("batched_peak_vs_batch1_min_ratio", Json::from(1.5f64)),
                (
                    "note",
                    "serving forward bit-exact vs the training path (asserted before \
                     timing, both formats); adaptive batching must buy >= 1.5x peak \
                     throughput over the batch-1 front end on the reloaded checkpoint"
                        .into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_5.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_5.json: {e}"),
    }

    println!(
        "acceptance gates: `peak` rows — batched front end >= 1.50x batch-1 peak \
         throughput; parity asserted bit-exact (training path vs both serving \
         formats) before every timed family."
    );
}
