//! Table 6 (§5.3) — post-training Importance Pruning sweep.
//!
//! Trains each dataset's All-ReLU SET-MLP without pruning, then applies
//! Importance Pruning ONCE at the end at the 5/10/15/20/25th percentile
//! and measures the accuracy drop — demonstrating the paper's claim that
//! pruning must be *integrated during training* (Table 2/Algorithm 2) to
//! remove many parameters without losing accuracy.
//!
//! Env: TSNN_SCALE=paper, TSNN_EPOCHS, TSNN_DATASETS.

use tsnn::bench::{env_usize, paper_scale, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::importance::prune_post_training;
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 500 } else { 10 });
    let datasets_env = std::env::var("TSNN_DATASETS")
        .unwrap_or_else(|_| "leukemia,higgs,madelon,fashion,cifar".into());

    let mut table = Table::new(
        "Table 6 — post-training Importance Pruning sweep",
        &["dataset", "model acc [%]", "params", "threshold", "acc [%]", "end_w"],
    );

    for name in datasets_env.split(',') {
        let spec = if paper {
            DatasetSpec::paper(name)
        } else {
            DatasetSpec::small(name)
        };
        let data = match tsnn::data::generate(&spec, &mut Rng::new(1)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        let mut cfg = if paper {
            TrainConfig::paper_preset(name)
        } else {
            TrainConfig::small_preset(name)
        };
        cfg.epochs = epochs;
        cfg.importance = None; // Table 6 prunes post-hoc
        let base = train_sequential(&cfg, &data, &mut Rng::new(42)).expect("train");
        let mut ws = base.model.alloc_workspace(256);

        for pct in [5.0, 10.0, 15.0, 20.0, 25.0] {
            let mut m = base.model.clone();
            let (_, remaining) = prune_post_training(&mut m, pct);
            let (_, acc) = m.evaluate(&data.x_test, &data.y_test, 256, &mut ws);
            table.row(vec![
                name.to_string(),
                format!("{:.2}", base.final_test_accuracy * 100.0),
                base.end_weights.to_string(),
                format!("{pct}th pct"),
                format!("{:.2}", acc * 100.0),
                remaining.to_string(),
            ]);
        }
    }

    table.emit("table6_post_pruning.csv");
    println!("paper reference (Table 6): post-hoc pruning loses accuracy quickly");
    println!("past ~10th percentile — integration during training wins.");
}
