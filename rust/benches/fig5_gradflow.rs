//! Fig. 5 — gradient flow of sparse MLPs: All-ReLU vs ReLU on the
//! CIFAR10-, FashionMNIST- and Madelon-like datasets (3 hidden layers).
//!
//! Gradient flow = ‖∇L‖² (first-order expected loss decrease per unit
//! learning rate); the paper shows All-ReLU keeps it consistently higher,
//! which is its explanation for the accuracy gains.
//!
//! Emits results/fig5_gradflow_<dataset>.csv with both series.

use tsnn::bench::{env_usize, paper_scale, write_artifact, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::nn::Activation;
use tsnn::prelude::*;
use tsnn::train::{train_sequential_opts, TrainOptions};

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 500 } else { 10 });
    let every = (epochs / 15).max(1);

    let mut table = Table::new(
        "Fig. 5 — mean gradient flow (higher is better)",
        &["dataset", "activation", "mean ||grad||^2", "final ||grad||^2"],
    );

    for name in ["cifar", "fashion", "madelon"] {
        let spec = if paper {
            DatasetSpec::paper(name)
        } else {
            DatasetSpec::small(name)
        };
        let data = tsnn::data::generate(&spec, &mut Rng::new(1)).expect("dataset");
        let mut csv = String::from("activation,epoch,grad_norm_sq,loss\n");

        for (act, label) in [
            (Activation::Relu, "relu"),
            (Activation::AllRelu { alpha: 0.75 }, "allrelu"),
        ] {
            let mut cfg = if paper {
                TrainConfig::paper_preset(name)
            } else {
                TrainConfig::small_preset(name)
            };
            cfg.epochs = epochs;
            cfg.activation = match (act, cfg.activation) {
                (Activation::Relu, _) => Activation::Relu,
                (_, Activation::AllRelu { alpha }) => Activation::AllRelu { alpha },
                (a, _) => a,
            };
            let r = train_sequential_opts(
                &cfg,
                &data,
                &mut Rng::new(42),
                TrainOptions {
                    gradflow_every: every,
                    verbose: false,
                    ..Default::default()
                },
            )
            .expect("train");
            let gf = r.gradflow.expect("gradflow enabled");
            let mean: f64 = gf.points.iter().map(|p| p.grad_norm_sq).sum::<f64>()
                / gf.points.len().max(1) as f64;
            let last = gf.points.last().map(|p| p.grad_norm_sq).unwrap_or(0.0);
            for p in &gf.points {
                csv.push_str(&format!("{label},{},{},{}\n", p.epoch, p.grad_norm_sq, p.loss));
            }
            table.row(vec![
                name.to_string(),
                label.into(),
                format!("{mean:.4e}"),
                format!("{last:.4e}"),
            ]);
        }
        let _ = write_artifact(&format!("fig5_gradflow_{name}.csv"), &csv);
    }

    table.emit("fig5_gradflow.csv");
    println!("paper reference (Fig. 5): All-ReLU maintains visibly higher");
    println!("gradient flow than ReLU on all three datasets.");
}
