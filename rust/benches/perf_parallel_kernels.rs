//! §Perf — sequential vs worker-sharded sparse kernels (DESIGN.md §4).
//!
//! Measures all three hot-path kernels across a batch × density × thread
//! grid, printing per-kernel speedups plus a combined fwd+bwd row (the
//! acceptance gate: ≥ 2× fwd+bwd throughput at batch 128 with 4+ threads
//! on a 4+-core host). The sharded kernels produce exactly the sequential
//! results, so each timed pair is also cross-checked for agreement.
//!
//! Knobs: TSNN_ITERS (default 12), TSNN_BATCHES (csv, default 32,128,256),
//! TSNN_THREADS (csv, default 2,4,<cores>).

use tsnn::bench::{env_usize, time_it, Table};
use tsnn::prelude::*;
use tsnn::sparse::{erdos_renyi_epsilon, ops};

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = match std::env::var(name) {
        Ok(s) => s.split(',').filter_map(|p| p.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    };
    v.retain(|&t| t >= 1);
    v.sort_unstable();
    v.dedup();
    if v.is_empty() {
        v.push(1);
    }
    v
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 12);
    let batches = env_csv("TSNN_BATCHES", &[32, 128, 256]);
    let cores = ops::available_threads();
    let threads_grid = env_csv("TSNN_THREADS", &[2, 4, cores]);

    println!(
        "host: {cores} cores; crossover PAR_MIN_WORK = {} MACs\n",
        ops::PAR_MIN_WORK
    );

    let mut table = Table::new(
        "§Perf — sequential vs worker-sharded sparse kernels",
        &["kernel", "shape", "eps", "batch", "threads", "seq ms", "par ms", "speedup"],
    );

    // (n_in, n_out, ε): fashion hidden, cifar-in, wide symmetric (≈2×
    // density), extreme-scale input layer.
    for &(n_in, n_out, eps) in &[
        (1000usize, 1000usize, 20.0f64),
        (3072, 4000, 20.0),
        (4000, 4000, 40.0),
        (65536, 4096, 5.0),
    ] {
        let mut rng = Rng::new(1);
        let w = erdos_renyi_epsilon(n_in, n_out, eps, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let shape = format!("{n_in}x{n_out}");
        for &batch in &batches {
            let x: Vec<f32> = (0..batch * n_in).map(|_| rng.normal()).collect();
            let dz: Vec<f32> = (0..batch * n_out).map(|_| rng.normal()).collect();
            let mut out = vec![0.0f32; batch * n_out];
            let mut dx = vec![0.0f32; batch * n_in];
            let mut dw = vec![0.0f32; nnz];

            // sequential reference timings
            let (fwd_seq, _) = time_it(2, iters, || {
                out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward(&x, batch, &w, &mut out);
            });
            let fwd_ref = out.clone();
            let (din_seq, _) = time_it(2, iters, || {
                ops::spmm_grad_input(&dz, batch, &w, &mut dx);
            });
            let din_ref = dx.clone();
            let (dwt_seq, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dw);
            });
            let dwt_ref = dw.clone();

            for &threads in &threads_grid {
                let (fwd_par, _) = time_it(2, iters, || {
                    out.iter_mut().for_each(|v| *v = 0.0);
                    ops::spmm_forward_threaded(&x, batch, &w, &mut out, threads);
                });
                assert_eq!(out, fwd_ref, "forward parity {shape} b{batch} t{threads}");
                let (din_par, _) = time_it(2, iters, || {
                    ops::spmm_grad_input_threaded(&dz, batch, &w, &mut dx, threads);
                });
                assert_eq!(dx, din_ref, "grad_input parity {shape} b{batch} t{threads}");
                let (dwt_par, _) = time_it(2, iters, || {
                    dw.iter_mut().for_each(|v| *v = 0.0);
                    ops::spmm_grad_weights_threaded(&x, &dz, batch, &w, &mut dw, threads);
                });
                assert_eq!(dw, dwt_ref, "grad_weights parity {shape} b{batch} t{threads}");

                for (kernel, seq, par) in [
                    ("spmm_forward", fwd_seq, fwd_par),
                    ("spmm_grad_input", din_seq, din_par),
                    ("spmm_grad_weights", dwt_seq, dwt_par),
                    ("fwd+bwd", fwd_seq + din_seq + dwt_seq, fwd_par + din_par + dwt_par),
                ] {
                    table.row(vec![
                        kernel.into(),
                        shape.clone(),
                        format!("{eps}"),
                        batch.to_string(),
                        threads.to_string(),
                        format!("{:.3}", seq * 1e3),
                        format!("{:.3}", par * 1e3),
                        format!("{:.2}x", seq / par.max(1e-12)),
                    ]);
                }
            }
        }
    }

    table.emit("perf_parallel_kernels.csv");

    // Acceptance summary: best fwd+bwd speedup at batch 128 with ≥4 threads.
    if cores >= 4 {
        println!(
            "acceptance gate: look for the `fwd+bwd` rows at batch 128, threads >= 4 \
             — target >= 2.00x on a 4+-core host."
        );
    } else {
        println!(
            "note: this host exposes {cores} cores; the >= 2x acceptance gate \
             needs a 4+-core machine."
        );
    }
}
