//! §Perf — sequential vs worker-sharded sparse kernels, and the fused
//! one-pass backward vs the two-kernel baseline (DESIGN.md §4–§5).
//!
//! Measures the hot-path kernels across a batch × density × thread grid,
//! printing per-kernel speedups plus a combined fwd+bwd row, and emits a
//! machine-readable `BENCH_2.json` at the repository root (per-kernel
//! ns/step, MACs/s, speedup vs sequential, thread count, shapes) so the
//! perf trajectory is tracked across PRs.
//!
//! Acceptance gates:
//!   * sharded fwd+bwd ≥ 2× sequential at batch 128 with 4+ threads on a
//!     4+-core host (PR 1);
//!   * fused backward ≥ 1.25× the two-kernel backward at batch ≥ 64,
//!     nnz ≥ 40k on the same thread budget (PR 2) — `backward_fused`
//!     rows, `speedup` column.
//!
//! Every timed pair is also cross-checked for exact agreement (the
//! sharded and fused kernels are bit-identical to their oracles).
//!
//! Knobs: TSNN_ITERS (default 12), TSNN_BATCHES (csv, default 32,128,256),
//! TSNN_THREADS (csv, default 2,4,<cores>), TSNN_REPO_ROOT (JSON
//! destination override).

use tsnn::bench::{env_usize, host_info, time_it, write_repo_root_json, Table};
use tsnn::prelude::*;
use tsnn::sparse::{erdos_renyi_epsilon, ops};
use tsnn::util::json::{obj, Json};

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = match std::env::var(name) {
        Ok(s) => s.split(',').filter_map(|p| p.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    };
    v.retain(|&t| t >= 1);
    v.sort_unstable();
    v.dedup();
    if v.is_empty() {
        v.push(1);
    }
    v
}

/// One emitted measurement: kernel × shape × batch × threads.
#[allow(clippy::too_many_arguments)]
fn json_row(
    kernel: &str,
    n_in: usize,
    n_out: usize,
    eps: f64,
    nnz: usize,
    batch: usize,
    threads: usize,
    baseline_secs: f64,
    secs: f64,
    macs: f64,
) -> Json {
    obj(vec![
        ("kernel", kernel.into()),
        ("n_in", n_in.into()),
        ("n_out", n_out.into()),
        ("eps", eps.into()),
        ("nnz", nnz.into()),
        ("batch", batch.into()),
        ("threads", threads.into()),
        ("baseline_ns_per_step", (baseline_secs * 1e9).into()),
        ("ns_per_step", (secs * 1e9).into()),
        ("macs_per_s", (macs / secs.max(1e-12)).into()),
        ("speedup", (baseline_secs / secs.max(1e-12)).into()),
    ])
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 12);
    let batches = env_csv("TSNN_BATCHES", &[32, 128, 256]);
    let cores = ops::available_threads();
    let threads_grid = env_csv("TSNN_THREADS", &[2, 4, cores]);

    println!(
        "host: {cores} cores; crossover PAR_MIN_WORK = {} MACs\n",
        ops::PAR_MIN_WORK
    );

    let mut table = Table::new(
        "§Perf — sequential vs sharded kernels, fused vs two-kernel backward",
        &["kernel", "shape", "eps", "batch", "threads", "base ms", "ms", "speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();

    // (n_in, n_out, ε): fashion hidden, cifar-in, wide symmetric (≈2×
    // density), extreme-scale input layer.
    for &(n_in, n_out, eps) in &[
        (1000usize, 1000usize, 20.0f64),
        (3072, 4000, 20.0),
        (4000, 4000, 40.0),
        (65536, 4096, 5.0),
    ] {
        let mut rng = Rng::new(1);
        let w = erdos_renyi_epsilon(n_in, n_out, eps, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let shape = format!("{n_in}x{n_out}");
        for &batch in &batches {
            let x: Vec<f32> = (0..batch * n_in).map(|_| rng.normal()).collect();
            let dz: Vec<f32> = (0..batch * n_out).map(|_| rng.normal()).collect();
            let mut out = vec![0.0f32; batch * n_out];
            let mut dx = vec![0.0f32; batch * n_in];
            let mut dw = vec![0.0f32; nnz];
            let macs = nnz as f64 * batch as f64;

            // sequential reference timings
            let (fwd_seq, _) = time_it(2, iters, || {
                out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward(&x, batch, &w, &mut out);
            });
            let fwd_ref = out.clone();
            let (din_seq, _) = time_it(2, iters, || {
                ops::spmm_grad_input(&dz, batch, &w, &mut dx);
            });
            let din_ref = dx.clone();
            let (dwt_seq, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dw);
            });
            let dwt_ref = dw.clone();

            // bias_grad rides the same grid (sequential; O(batch·n_out)
            // adds, negligible next to the spmm kernels but tracked so a
            // regression is visible)
            let mut db = vec![0.0f32; n_out];
            let (bias_secs, _) = time_it(2, iters, || {
                db.iter_mut().for_each(|v| *v = 0.0);
                ops::bias_grad(&dz, batch, n_out, &mut db);
            });
            rows.push(json_row(
                "bias_grad",
                n_in,
                n_out,
                eps,
                nnz,
                batch,
                1,
                bias_secs,
                bias_secs,
                batch as f64 * n_out as f64,
            ));

            for &threads in &threads_grid {
                let (fwd_par, _) = time_it(2, iters, || {
                    out.iter_mut().for_each(|v| *v = 0.0);
                    ops::spmm_forward_threaded(&x, batch, &w, &mut out, threads);
                });
                assert_eq!(out, fwd_ref, "forward parity {shape} b{batch} t{threads}");
                let (din_par, _) = time_it(2, iters, || {
                    ops::spmm_grad_input_threaded(&dz, batch, &w, &mut dx, threads);
                });
                assert_eq!(dx, din_ref, "grad_input parity {shape} b{batch} t{threads}");
                let (dwt_par, _) = time_it(2, iters, || {
                    dw.iter_mut().for_each(|v| *v = 0.0);
                    ops::spmm_grad_weights_threaded(&x, &dz, batch, &w, &mut dw, threads);
                });
                assert_eq!(dw, dwt_ref, "grad_weights parity {shape} b{batch} t{threads}");

                // fused one-pass backward vs the two-kernel pair on the
                // SAME thread budget (the PR-2 acceptance comparison)
                let (fused, _) = time_it(2, iters, || {
                    dw.iter_mut().for_each(|v| *v = 0.0);
                    ops::spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, threads);
                });
                assert_eq!(dx, din_ref, "fused dx parity {shape} b{batch} t{threads}");
                assert_eq!(dw, dwt_ref, "fused dw parity {shape} b{batch} t{threads}");
                let two_kernel = din_par + dwt_par;

                for (kernel, base, secs, m) in [
                    ("spmm_forward", fwd_seq, fwd_par, macs),
                    ("spmm_grad_input", din_seq, din_par, macs),
                    ("spmm_grad_weights", dwt_seq, dwt_par, macs),
                    ("backward_fused", two_kernel, fused, 2.0 * macs),
                    (
                        "fwd+bwd",
                        fwd_seq + din_seq + dwt_seq,
                        fwd_par + fused,
                        3.0 * macs,
                    ),
                ] {
                    table.row(vec![
                        kernel.into(),
                        shape.clone(),
                        format!("{eps}"),
                        batch.to_string(),
                        threads.to_string(),
                        format!("{:.3}", base * 1e3),
                        format!("{:.3}", secs * 1e3),
                        format!("{:.2}x", base / secs.max(1e-12)),
                    ]);
                    rows.push(json_row(
                        kernel, n_in, n_out, eps, nnz, batch, threads, base, secs, m,
                    ));
                }
            }
        }
    }

    table.emit("perf_parallel_kernels.csv");

    let doc = obj(vec![
        ("bench", "perf_parallel_kernels".into()),
        ("pr", 2usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("host_threads", cores.into()),
        ("iters", iters.into()),
        ("par_min_work", ops::PAR_MIN_WORK.into()),
        ("block", 8usize.into()),
        (
            "acceptance",
            obj(vec![
                ("backward_fused_min_speedup", Json::from(1.25f64)),
                ("at_batch_ge", 64usize.into()),
                ("at_nnz_ge", 40_000usize.into()),
                (
                    "note",
                    "speedup is vs the two-kernel backward at the SAME thread count".into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_2.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_2.json: {e}"),
    }

    // Acceptance summaries.
    if cores >= 4 {
        println!(
            "acceptance gates: `fwd+bwd` rows at batch 128, threads >= 4 — target \
             >= 2.00x; `backward_fused` rows at batch >= 64 — target >= 1.25x \
             vs the two-kernel backward on the same thread budget."
        );
    } else {
        println!(
            "note: this host exposes {cores} cores; the >= 2x fwd+bwd gate \
             needs a 4+-core machine (the >= 1.25x fused gate applies at any \
             thread count, including 1)."
        );
    }
}
