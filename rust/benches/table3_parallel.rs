//! Table 3 — parallel training comparison.
//!
//! For Higgs / FashionMNIST / CIFAR10 (-like) datasets:
//! WASSP-SGD and WASAP-SGD (± Importance Pruning), the sequential
//! baseline, and the masked-dense XLA engine standing in for "Keras CPU"
//! (per-epoch time extrapolated). Reports accuracy, training time, CPU
//! utilisation and peak memory — the paper's Table 3 row format.
//!
//! Env: TSNN_SCALE=paper, TSNN_EPOCHS, TSNN_WORKERS.

use tsnn::bench::{env_usize, fmt_duration, paper_scale, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::{run_parallel, ParallelConfig};
use tsnn::importance::ImportanceConfig;
use tsnn::prelude::*;
use tsnn::runtime::{default_artifacts_dir, Manifest, MaskedDenseTrainer};
use tsnn::train::train_sequential;
use tsnn::util::{cpu_time_secs, peak_rss_mib, Timer};

fn importance_cfg(epochs: usize) -> ImportanceConfig {
    ImportanceConfig {
        start_epoch: (epochs * 2 / 5).max(1),
        period: (epochs / 10).max(1),
        percentile: 5.0,
        min_connections: 64,
    }
}

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 500 } else { 6 });
    let workers = env_usize("TSNN_WORKERS", 5);
    let datasets_env =
        std::env::var("TSNN_DATASETS").unwrap_or_else(|_| "higgs,fashion,cifar".into());

    let mut table = Table::new(
        "Table 3 — parallel vs sequential vs masked-dense (framework comparator)",
        &["dataset", "framework", "imp. pruning", "acc [%]", "time", "cpu [%]", "mem [MB]"],
    );

    let manifest = Manifest::load(&default_artifacts_dir()).ok();

    for name in datasets_env.split(',') {
        let spec = if paper {
            DatasetSpec::paper(name)
        } else {
            DatasetSpec::small(name)
        };
        let data = match tsnn::data::generate(&spec, &mut Rng::new(1)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        let base_cfg = if paper {
            TrainConfig::paper_preset(name)
        } else {
            TrainConfig::small_preset(name)
        };

        // measure one scenario, tracking cpu% and peak rss
        let mut run = |framework: &str, pruning: bool| {
            let mut cfg = base_cfg.clone();
            cfg.epochs = epochs;
            cfg.importance = pruning.then(|| importance_cfg(epochs));
            let cpu0 = cpu_time_secs();
            let t = Timer::start();
            let (acc, _steps) = match framework {
                "Sequential" => {
                    let r = train_sequential(&cfg, &data, &mut Rng::new(42)).expect("seq");
                    (r.best_test_accuracy, 0u64)
                }
                algo => {
                    let pcfg = ParallelConfig {
                        workers,
                        phase1_epochs: (epochs * 4 / 5).max(1),
                        phase2_epochs: (epochs / 5).max(1),
                        synchronous: algo == "WASSP-SGD",
            hot_start: true,
            grad_clip: 5.0,
        };
                    let r = run_parallel(&cfg, &pcfg, &data, &mut Rng::new(42)).expect("par");
                    (r.final_test_accuracy, r.server_stats.steps)
                }
            };
            let wall = t.secs();
            let cpu_pct = 100.0 * (cpu_time_secs() - cpu0) / wall.max(1e-9)
                / std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1) as f64;
            table.row(vec![
                name.to_string(),
                framework.into(),
                if pruning { "yes" } else { "no" }.into(),
                format!("{:.2}", acc * 100.0),
                fmt_duration(wall),
                format!("{cpu_pct:.0}"),
                format!("{:.0}", peak_rss_mib()),
            ]);
        };

        run("WASSP-SGD", false);
        run("WASSP-SGD", true);
        run("WASAP-SGD", false);
        run("WASAP-SGD", true);
        run("Sequential", false);
        run("Sequential", true);

        // masked-dense comparator ("Keras CPU"): measure a few epochs and
        // extrapolate to the same epoch budget.
        if let Some(m) = &manifest {
            if let Some(arch) = m.get(name) {
                let mut rng = Rng::new(42);
                match MaskedDenseTrainer::new(arch, base_cfg.epsilon, &mut rng) {
                    Ok(mut trainer) => {
                        let probe = 2usize;
                        let t = Timer::start();
                        for _ in 0..probe {
                            let _ = trainer.train_epoch(&data, 0.01, &mut rng);
                            trainer.evolve(0.3, &mut rng);
                        }
                        let per_epoch = t.secs() / probe as f64;
                        let acc = trainer.evaluate(&data).unwrap_or(f32::NAN);
                        table.row(vec![
                            name.to_string(),
                            "masked-dense XLA (\"Keras\")".into(),
                            "no".into(),
                            format!("{:.2} (@{probe} ep)", acc * 100.0),
                            format!("{} (extrap.)", fmt_duration(per_epoch * epochs as f64)),
                            "-".into(),
                            format!("{:.0}", peak_rss_mib()),
                        ]);
                    }
                    Err(e) => eprintln!("masked baseline for {name} failed: {e}"),
                }
            }
        }
    }

    table.emit("table3_parallel.csv");
    println!("paper reference (Table 3): WASAP > WASSP in accuracy and time;");
    println!("parallel ≈ 2x faster than sequential; both beat Keras-CPU wall-clock.");
}
