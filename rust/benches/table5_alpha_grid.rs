//! Table 5 / Fig. 19 — grid search over the All-ReLU slope α on the
//! FashionMNIST-like dataset. α = 0 degenerates to ReLU; the paper finds
//! every α > 0.05 beats ReLU, with the best at α = 0.6.
//!
//! Env: TSNN_SCALE=paper, TSNN_EPOCHS, TSNN_TRIALS.

use tsnn::bench::{env_usize, paper_scale, write_artifact, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::nn::Activation;
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 500 } else { 10 });
    let trials = env_usize("TSNN_TRIALS", if paper { 5 } else { 1 });
    let alphas = [0.0f32, 0.05, 0.1, 0.2, 0.25, 0.5, 0.6, 0.75, 0.8, 0.9];

    let spec = if paper {
        DatasetSpec::paper("fashion")
    } else {
        DatasetSpec::small("fashion")
    };
    let data = tsnn::data::generate(&spec, &mut Rng::new(1)).expect("dataset");

    let mut table = Table::new(
        "Table 5 — All-ReLU slope α grid search (fashion-like)",
        &["alpha", "best acc [%]", "mean acc [%]"],
    );
    let mut curves = String::from("alpha,trial,epoch,test_acc\n");

    let mut best_alpha = (0.0f32, 0.0f32);
    for &alpha in &alphas {
        let mut best = 0.0f32;
        let mut mean = 0.0f64;
        for trial in 0..trials {
            let mut cfg = if paper {
                TrainConfig::paper_preset("fashion")
            } else {
                TrainConfig::small_preset("fashion")
            };
            cfg.epochs = epochs;
            cfg.activation = if alpha == 0.0 {
                Activation::Relu
            } else {
                Activation::AllRelu { alpha }
            };
            cfg.seed = 42 + trial as u64;
            let r = train_sequential(&cfg, &data, &mut Rng::new(cfg.seed)).expect("train");
            best = best.max(r.best_test_accuracy);
            mean += r.best_test_accuracy as f64;
            for e in &r.epochs {
                if !e.test_accuracy.is_nan() {
                    curves.push_str(&format!("{alpha},{trial},{},{}\n", e.epoch, e.test_accuracy));
                }
            }
        }
        if best > best_alpha.1 {
            best_alpha = (alpha, best);
        }
        table.row(vec![
            format!("{alpha}"),
            format!("{:.2}", best * 100.0),
            format!("{:.2}", mean / trials as f64 * 100.0),
        ]);
    }

    table.emit("table5_alpha_grid.csv");
    let _ = write_artifact("fig19_alpha_curves.csv", &curves);
    println!(
        "best alpha: {} (acc {:.2}%) — paper found 0.6 best on FashionMNIST,\n\
         with all alpha > 0.05 beating ReLU (alpha row 0).",
        best_alpha.0,
        best_alpha.1 * 100.0
    );
}
