//! Ablation — WASAP-SGD stabilisation knobs.
//!
//! The paper reports that asynchrony introduces implicit momentum
//! (Mitliagkas et al.) and that WASAP "benefits from larger learning
//! rates for the first few epochs". This ablation quantifies the two
//! guardrails this implementation adds on top (see EXPERIMENTS.md
//! "Known deltas"): the hot-start LR wrap and worker-side gradient
//! clipping, plus a phase-2 on/off comparison (the SWA-style averaging
//! contribution of Algorithm 1).
//!
//! Env: TSNN_EPOCHS (default 12), TSNN_WORKERS (default 5),
//!      TSNN_TRIALS (default 3).

use tsnn::bench::{env_usize, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::coordinator::{run_parallel, ParallelConfig};
use tsnn::prelude::*;

fn main() {
    let epochs = env_usize("TSNN_EPOCHS", 12);
    let workers = env_usize("TSNN_WORKERS", 5);
    let trials = env_usize("TSNN_TRIALS", 3);

    let spec = DatasetSpec::small("higgs");
    let data = tsnn::data::generate(&spec, &mut Rng::new(1)).expect("dataset");
    let mut cfg = TrainConfig::small_preset("higgs");
    cfg.epochs = epochs;

    let mut table = Table::new(
        "Ablation — WASAP stabilisation knobs (higgs-like)",
        &["hot-start", "grad clip", "phase 2", "mean final acc [%]", "min acc [%]", "mean staleness"],
    );

    for (hot, clip, phase2) in [
        (true, 5.0f32, true),   // defaults
        (false, 5.0, true),     // no hot-start
        (true, 0.0, true),      // no clipping
        (false, 0.0, true),     // neither guardrail
        (true, 5.0, false),     // no phase-2 averaging
    ] {
        let mut accs = Vec::new();
        let mut stale = 0.0f64;
        for t in 0..trials {
            let pcfg = ParallelConfig {
                workers,
                phase1_epochs: (epochs * 4 / 5).max(1),
                phase2_epochs: if phase2 { (epochs / 5).max(1) } else { 0 },
                synchronous: false,
                hot_start: hot,
                grad_clip: clip,
            };
            let mut local = cfg.clone();
            local.seed = 42 + t as u64;
            let r = run_parallel(&local, &pcfg, &data, &mut Rng::new(local.seed))
                .expect("wasap");
            accs.push(r.final_test_accuracy);
            stale += r.server_stats.mean_staleness;
        }
        let mean = accs.iter().sum::<f32>() / accs.len() as f32;
        let min = accs.iter().cloned().fold(f32::INFINITY, f32::min);
        table.row(vec![
            hot.to_string(),
            format!("{clip}"),
            phase2.to_string(),
            format!("{:.2}", mean * 100.0),
            format!("{:.2}", min * 100.0),
            format!("{:.2}", stale / trials as f64),
        ]);
    }

    table.emit("ablation_wasap.csv");
    println!("reading: min-acc rows expose instability; without guardrails the");
    println!("async run occasionally collapses to the majority-class predictor.");
}
