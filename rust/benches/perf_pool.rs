//! §Perf — persistent worker pool vs scoped-spawn dispatch (DESIGN.md
//! §9): the spawn-once/park pool must make parallel dispatch so cheap
//! that the old `PAR_MIN_WORK` crossover gap — layers that used to run
//! sequential because a scoped spawn would eat the win — becomes
//! parallel territory. Emits a machine-readable `BENCH_4.json` at the
//! repository root.
//!
//! Four measurement families:
//!   * `dispatch` — raw scatter-gather cost: warm `WorkerPool::run` vs a
//!     `thread::scope` spawn of the same shard count (no-op shards).
//!     Acceptance: pool ≥ 10× cheaper at equal shard count.
//!   * per-kernel rows — all four sharded kernels on layers sized inside
//!     the OLD sub-crossover gap (`batch·nnz ≈ 2¹⁸ < PAR_MIN_WORK`),
//!     pooled dispatch vs the sequential kernel the old path fell back
//!     to. Parity-asserted (exact) before timing.
//!   * `crossover` — work sweep across 2¹³‥2²² MACs re-deriving
//!     `POOL_MIN_WORK` (the work level where pooled speedup crosses 1).
//!   * `epoch` — end-to-end training epochs (train steps + evolution) on
//!     a layer in the old gap. Acceptance: ≥ 1.2× vs the sequential
//!     baseline, bit-exact parity asserted first.
//!
//! Knobs: TSNN_ITERS (default 20), TSNN_THREADS (csv, default
//! 2,4,<cores>), TSNN_EPOCHS (default 6), TSNN_REPO_ROOT.

use tsnn::bench::{env_usize, host_info, time_it, write_repo_root_json, Table};
use tsnn::prelude::*;
use tsnn::set::{EvolutionConfig, EvolutionEngine};
use tsnn::sparse::{erdos_renyi_epsilon, ops, Exec, WorkerPool};
use tsnn::util::json::{obj, Json};

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = match std::env::var(name) {
        Ok(s) => s.split(',').filter_map(|p| p.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    };
    v.retain(|&t| t >= 2);
    v.sort_unstable();
    v.dedup();
    if v.is_empty() {
        v.push(2);
    }
    v
}

fn random_vec(rng: &mut Rng, n: usize, zero_frac: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.bernoulli(zero_frac) {
                0.0
            } else {
                rng.normal()
            }
        })
        .collect()
}

/// One gap-model training epoch: full pass of train steps + one SET
/// evolution epoch, everything on `ws`'s dispatch budget.
#[allow(clippy::too_many_arguments)]
fn run_epoch(
    mlp: &mut SparseMlp,
    x: &[f32],
    y: &[u32],
    n_feat: usize,
    batch: usize,
    ws: &mut tsnn::model::Workspace,
    engine: &mut EvolutionEngine,
    evo: &EvolutionConfig,
    rng: &mut Rng,
    threads: usize,
) {
    let opt = MomentumSgd::default();
    let n = y.len();
    let mut start = 0usize;
    while start < n {
        let end = (start + batch).min(n);
        mlp.train_step(
            &x[start * n_feat..end * n_feat],
            &y[start..end],
            &opt,
            0.01,
            None,
            ws,
            rng,
        );
        start = end;
    }
    engine.evolve_model(mlp, evo, rng, threads).unwrap();
}

fn assert_models_equal(a: &SparseMlp, b: &SparseMlp, label: &str) {
    for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.weights, lb.weights, "{label}: layer {l} weights");
        assert_eq!(la.velocity, lb.velocity, "{label}: layer {l} velocity");
        assert_eq!(la.bias, lb.bias, "{label}: layer {l} bias");
    }
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 20);
    let epochs = env_usize("TSNN_EPOCHS", 6);
    let cores = ops::available_threads();
    let threads_grid = env_csv("TSNN_THREADS", &[2, 4, cores]);
    let mut rows: Vec<Json> = Vec::new();

    println!(
        "host: {cores} cores; crossover: POOL_MIN_WORK = {} (warm pool) vs \
         PAR_MIN_WORK = {} (scoped spawn)\n",
        ops::POOL_MIN_WORK,
        ops::PAR_MIN_WORK
    );

    // ---- 1. dispatch microbenchmark: warm pool vs scoped spawn ----
    let mut disp = Table::new(
        "§Perf — dispatch cost: warm pool wakeup vs scoped thread spawn (no-op shards)",
        &["shards", "spawn µs", "pool µs", "ratio"],
    );
    let disp_iters = iters.max(50);
    for &shards in &threads_grid {
        let (spawn_secs, _) = time_it(5, disp_iters, || {
            std::thread::scope(|scope| {
                for _ in 1..shards {
                    scope.spawn(|| std::hint::black_box(()));
                }
                std::hint::black_box(());
            });
        });
        let pool = WorkerPool::new(shards);
        let (pool_secs, _) = time_it(5, disp_iters, || {
            pool.run(shards, |_| {
                std::hint::black_box(());
            });
        });
        let ratio = spawn_secs / pool_secs.max(1e-12);
        disp.row(vec![
            shards.to_string(),
            format!("{:.2}", spawn_secs * 1e6),
            format!("{:.2}", pool_secs * 1e6),
            format!("{ratio:.1}x"),
        ]);
        rows.push(obj(vec![
            ("op", "dispatch".into()),
            ("shards", shards.into()),
            ("spawn_ns", (spawn_secs * 1e9).into()),
            ("pool_ns", (pool_secs * 1e9).into()),
            ("ratio", ratio.into()),
        ]));
    }
    disp.emit("perf_pool_dispatch.csv");

    // ---- 2. per-kernel speedups inside the OLD sub-crossover gap ----
    // batch·nnz ≈ 2¹⁸ — the old scoped path fell back to sequential
    // here, so "pooled vs sequential" is exactly the win the pool opens.
    let mut gap = Table::new(
        "§Perf — kernels in the old sub-crossover gap (batch·nnz ≈ 2^18): \
         sequential (old behaviour) vs pooled dispatch",
        &["kernel", "shape", "batch", "work", "threads", "seq µs", "pool µs", "speedup"],
    );
    for &(n_in, n_out, eps, batch) in &[
        (1000usize, 1000usize, 20.0f64, 8usize),
        (512, 512, 20.0, 16),
        (256, 256, 16.0, 64),
    ] {
        let mut rng = Rng::new(1);
        let w = erdos_renyi_epsilon(n_in, n_out, eps, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let work = batch * nnz;
        assert!(
            work >= ops::POOL_MIN_WORK && work < ops::PAR_MIN_WORK,
            "{n_in}x{n_out} b{batch}: work {work} must sit in the old gap"
        );
        let shape = format!("{n_in}x{n_out}");
        let x = random_vec(&mut rng, batch * n_in, 0.3);
        let dz = random_vec(&mut rng, batch * n_out, 0.0);
        let mut out = vec![0.0f32; batch * n_out];
        let mut dx = vec![0.0f32; batch * n_in];
        let mut dw = vec![0.0f32; nnz];

        // sequential references (+ parity baselines)
        let (fwd_seq, _) = time_it(2, iters, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_forward(&x, batch, &w, &mut out);
        });
        let fwd_ref = out.clone();
        let (din_seq, _) = time_it(2, iters, || {
            ops::spmm_grad_input(&dz, batch, &w, &mut dx);
        });
        let din_ref = dx.clone();
        let (dwt_seq, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dw);
        });
        let dwt_ref = dw.clone();
        let (fused_seq, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, 1);
        });

        for &threads in &threads_grid {
            let pool = WorkerPool::new(threads);
            let exec = Exec::pooled(&pool);
            let (fwd_pool, _) = time_it(2, iters, || {
                out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward_exec(&x, batch, &w, &mut out, exec);
            });
            assert_eq!(out, fwd_ref, "forward parity {shape} t{threads}");
            let (din_pool, _) = time_it(2, iters, || {
                ops::spmm_grad_input_exec(&dz, batch, &w, &mut dx, exec);
            });
            assert_eq!(dx, din_ref, "grad_input parity {shape} t{threads}");
            let (dwt_pool, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_grad_weights_exec(&x, &dz, batch, &w, &mut dw, exec);
            });
            assert_eq!(dw, dwt_ref, "grad_weights parity {shape} t{threads}");
            let (fused_pool, _) = time_it(2, iters, || {
                dw.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_backward_fused_exec(&x, &dz, batch, &w, &mut dx, &mut dw, exec);
            });
            assert_eq!(dx, din_ref, "fused dx parity {shape} t{threads}");
            assert_eq!(dw, dwt_ref, "fused dw parity {shape} t{threads}");

            for (kernel, seq, pooled) in [
                ("spmm_forward", fwd_seq, fwd_pool),
                ("spmm_grad_input", din_seq, din_pool),
                ("spmm_grad_weights", dwt_seq, dwt_pool),
                ("backward_fused", fused_seq, fused_pool),
            ] {
                gap.row(vec![
                    kernel.into(),
                    shape.clone(),
                    batch.to_string(),
                    work.to_string(),
                    threads.to_string(),
                    format!("{:.2}", seq * 1e6),
                    format!("{:.2}", pooled * 1e6),
                    format!("{:.2}x", seq / pooled.max(1e-12)),
                ]);
                rows.push(obj(vec![
                    ("op", "gap_kernel".into()),
                    ("kernel", kernel.into()),
                    ("n_in", n_in.into()),
                    ("n_out", n_out.into()),
                    ("nnz", nnz.into()),
                    ("batch", batch.into()),
                    ("work", work.into()),
                    ("threads", threads.into()),
                    ("seq_ns", (seq * 1e9).into()),
                    ("pool_ns", (pooled * 1e9).into()),
                    ("speedup", (seq / pooled.max(1e-12)).into()),
                ]));
            }
        }
    }
    gap.emit("perf_pool_gap_kernels.csv");

    // ---- 3. crossover sweep: where does pooled dispatch start paying? ----
    let mut sweep = Table::new(
        "§Perf — pooled-dispatch crossover sweep (forward kernel, 4-thread pool)",
        &["work (batch·nnz)", "batch", "seq µs", "pool µs", "speedup"],
    );
    {
        let mut rng = Rng::new(2);
        let w = erdos_renyi_epsilon(256, 256, 16.0, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let threads = threads_grid.first().copied().unwrap_or(4).max(4);
        let pool = WorkerPool::new(threads);
        let mut batch = 2usize;
        while batch * nnz <= (1 << 22) {
            let x = random_vec(&mut rng, batch * 256, 0.3);
            let mut seq_out = vec![0.0f32; batch * 256];
            let mut pool_out = vec![0.0f32; batch * 256];
            let (seq_secs, _) = time_it(2, iters, || {
                seq_out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward(&x, batch, &w, &mut seq_out);
            });
            let exec = Exec::pooled(&pool);
            let (pool_secs, _) = time_it(2, iters, || {
                pool_out.iter_mut().for_each(|v| *v = 0.0);
                ops::spmm_forward_exec(&x, batch, &w, &mut pool_out, exec);
            });
            assert_eq!(seq_out, pool_out, "sweep parity b{batch}");
            let work = batch * nnz;
            sweep.row(vec![
                work.to_string(),
                batch.to_string(),
                format!("{:.2}", seq_secs * 1e6),
                format!("{:.2}", pool_secs * 1e6),
                format!("{:.2}x", seq_secs / pool_secs.max(1e-12)),
            ]);
            rows.push(obj(vec![
                ("op", "crossover".into()),
                ("work", work.into()),
                ("batch", batch.into()),
                ("nnz", nnz.into()),
                ("threads", threads.into()),
                ("seq_ns", (seq_secs * 1e9).into()),
                ("pool_ns", (pool_secs * 1e9).into()),
                ("speedup", (seq_secs / pool_secs.max(1e-12)).into()),
            ]));
            batch *= 2;
        }
    }
    sweep.emit("perf_pool_crossover.csv");

    // ---- 4. end-to-end epochs on a gap-sized layer ----
    // [1000 → 1000 → 10] at ε = 4 puts the dominant layer at
    // batch·nnz ≈ 2¹⁸ — squarely in the gap the pool opens up.
    let mut epoch_table = Table::new(
        "§Perf — end-to-end training epoch (steps + evolution) on a \
         sub-crossover-gap model: sequential vs pooled",
        &["threads", "seq ms/epoch", "pool ms/epoch", "speedup"],
    );
    {
        let sizes = [1000usize, 1000, 10];
        let (batch, n_samples, n_feat) = (32usize, 512usize, sizes[0]);
        let evo = EvolutionConfig {
            zeta: 0.3,
            init: WeightInit::HeUniform,
        };
        let mut rng = Rng::new(3);
        let base = SparseMlp::new(
            &sizes,
            4.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        let work = batch * base.layers[0].weights.nnz();
        assert!(
            work >= ops::POOL_MIN_WORK && work < ops::PAR_MIN_WORK,
            "epoch model must sit in the old gap, work = {work}"
        );
        let x = random_vec(&mut rng, n_samples * n_feat, 0.5);
        let y: Vec<u32> = (0..n_samples).map(|i| (i % sizes[2]) as u32).collect();

        let time_epochs = |threads: usize| -> f64 {
            let mut mlp = base.clone();
            let mut ws = mlp.alloc_workspace(batch);
            ws.kernel_threads = threads;
            ws.ensure_pool();
            let mut engine = match ws.pool() {
                Some(p) => EvolutionEngine::with_pool(p),
                None => EvolutionEngine::new(),
            };
            let mut rng = Rng::new(11);
            // one warm epoch (pool spawn, buffer sizing), then timed ones
            run_epoch(
                &mut mlp, &x, &y, n_feat, batch, &mut ws, &mut engine, &evo, &mut rng, threads,
            );
            let (secs, _) = time_it(0, epochs, || {
                run_epoch(
                    &mut mlp, &x, &y, n_feat, batch, &mut ws, &mut engine, &evo, &mut rng,
                    threads,
                );
            });
            secs
        };

        // bit-exact parity of the full epoch loop before timing: the
        // kernel-threads invariance guarantee end to end
        for &threads in &threads_grid {
            let run_to_model = |threads: usize| -> SparseMlp {
                let mut mlp = base.clone();
                let mut ws = mlp.alloc_workspace(batch);
                ws.kernel_threads = threads;
                ws.ensure_pool();
                let mut engine = match ws.pool() {
                    Some(p) => EvolutionEngine::with_pool(p),
                    None => EvolutionEngine::new(),
                };
                let mut rng = Rng::new(11);
                for _ in 0..2 {
                    run_epoch(
                        &mut mlp, &x, &y, n_feat, batch, &mut ws, &mut engine, &evo, &mut rng,
                        threads,
                    );
                }
                mlp
            };
            assert_models_equal(
                &run_to_model(1),
                &run_to_model(threads),
                &format!("epoch parity t{threads}"),
            );
        }

        let seq_secs = time_epochs(1);
        for &threads in &threads_grid {
            let pool_secs = time_epochs(threads);
            let speedup = seq_secs / pool_secs.max(1e-12);
            epoch_table.row(vec![
                threads.to_string(),
                format!("{:.3}", seq_secs * 1e3),
                format!("{:.3}", pool_secs * 1e3),
                format!("{speedup:.2}x"),
            ]);
            rows.push(obj(vec![
                ("op", "epoch".into()),
                ("work", work.into()),
                ("batch", batch.into()),
                ("threads", threads.into()),
                ("seq_ns", (seq_secs * 1e9).into()),
                ("pool_ns", (pool_secs * 1e9).into()),
                ("speedup", speedup.into()),
            ]));
        }
    }
    epoch_table.emit("perf_pool_epoch.csv");

    let doc = obj(vec![
        ("bench", "perf_pool".into()),
        ("pr", 4usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("host_threads", cores.into()),
        ("iters", iters.into()),
        ("pool_min_work", ops::POOL_MIN_WORK.into()),
        ("par_min_work", ops::PAR_MIN_WORK.into()),
        (
            "acceptance",
            obj(vec![
                ("pool_dispatch_vs_spawn_min_ratio", Json::from(10.0f64)),
                ("epoch_min_speedup", Json::from(1.2f64)),
                ("at_epoch_work", (1usize << 18).into()),
                (
                    "note",
                    "dispatch ratio at equal shard count; epoch speedup vs the sequential \
                     baseline on a layer in the old sub-crossover gap, parity-asserted \
                     before timing"
                        .into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_4.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_4.json: {e}"),
    }

    println!(
        "acceptance gates: `dispatch` rows — pool >= 10x cheaper than scoped spawn \
         at equal shard count; `epoch` rows — >= 1.20x end-to-end vs sequential on \
         the 2^18-work gap model (old behaviour was sequential there)."
    );
}
