//! §Perf — topology-evolution step: the in-place worker-sharded engine
//! (DESIGN.md §8) vs the sequential SET oracle, across layer shapes ×
//! thread counts, plus the fused importance+SET epoch vs the two-call
//! oracle. Emits a machine-readable `BENCH_3.json` at the repository
//! root (evolution-step ns/epoch, speedup vs the sequential oracle) so
//! the perf trajectory is tracked across PRs.
//!
//! Acceptance gate (PR 3): engine evolution epoch ≥ 1.5× the sequential
//! oracle at nnz ≥ 100k with 4+ threads — and bit-exact parity at every
//! thread count, asserted here before any timing.
//!
//! Knobs: TSNN_ITERS (default 10), TSNN_THREADS (csv, default
//! 1,2,4,<cores>), TSNN_REPO_ROOT (JSON destination override).

use tsnn::bench::{env_usize, host_info, time_it, write_repo_root_json, Table};
use tsnn::importance::{self, ImportanceConfig};
use tsnn::nn::Activation;
use tsnn::prelude::*;
use tsnn::set::{self, EvolutionConfig, EvolutionEngine};
use tsnn::sparse::ops;
use tsnn::util::json::{obj, Json};

fn env_csv(name: &str, default: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = match std::env::var(name) {
        Ok(s) => s.split(',').filter_map(|p| p.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    };
    v.retain(|&t| t >= 1);
    v.sort_unstable();
    v.dedup();
    if v.is_empty() {
        v.push(1);
    }
    v
}

/// One emitted measurement: op × shape × threads.
#[allow(clippy::too_many_arguments)]
fn json_row(
    op: &str,
    n_in: usize,
    n_out: usize,
    eps: f64,
    nnz: usize,
    threads: usize,
    baseline_secs: f64,
    secs: f64,
) -> Json {
    obj(vec![
        ("op", op.into()),
        ("n_in", n_in.into()),
        ("n_out", n_out.into()),
        ("eps", eps.into()),
        ("nnz", nnz.into()),
        ("threads", threads.into()),
        ("baseline_ns_per_epoch", (baseline_secs * 1e9).into()),
        ("ns_per_epoch", (secs * 1e9).into()),
        ("speedup", (baseline_secs / secs.max(1e-12)).into()),
    ])
}

fn single_layer(n_in: usize, n_out: usize, eps: f64, seed: u64) -> SparseMlp {
    let mut rng = Rng::new(seed);
    SparseMlp::new(
        &[n_in, n_out],
        eps,
        Activation::Relu,
        &WeightInit::HeUniform,
        &mut rng,
    )
    .unwrap()
}

fn assert_engine_matches_oracle(
    base: &SparseMlp,
    cfg: &EvolutionConfig,
    threads: usize,
    label: &str,
) {
    let (mut a, mut b) = (base.clone(), base.clone());
    let (mut ra, mut rb) = (Rng::new(7), Rng::new(7));
    set::evolve_model(&mut a, cfg, &mut ra).unwrap();
    let mut engine = EvolutionEngine::new();
    engine.evolve_model(&mut b, cfg, &mut rb, threads).unwrap();
    for (l, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.weights, lb.weights, "parity {label} layer {l} weights");
        assert_eq!(la.velocity, lb.velocity, "parity {label} layer {l} velocity");
    }
}

fn main() {
    let iters = env_usize("TSNN_ITERS", 10);
    let cores = ops::available_threads();
    let threads_grid = env_csv("TSNN_THREADS", &[1, 2, 4, cores]);
    let cfg = EvolutionConfig {
        zeta: 0.3,
        init: WeightInit::HeUniform,
    };

    println!("host: {cores} cores; ζ = {}\n", cfg.zeta);

    let mut table = Table::new(
        "§Perf — evolution step: sequential oracle vs in-place sharded engine",
        &["op", "shape", "eps", "nnz", "threads", "oracle ms", "engine ms", "speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();

    // (n_in, n_out, ε): fashion hidden, cifar-in, wide symmetric,
    // extreme-scale input layer — the perf_parallel_kernels shapes.
    for &(n_in, n_out, eps) in &[
        (1000usize, 1000usize, 20.0f64),
        (3072, 4000, 20.0),
        (4000, 4000, 40.0),
        (65536, 4096, 5.0),
    ] {
        let base = single_layer(n_in, n_out, eps, 1);
        let nnz = base.weight_count();
        let shape = format!("{n_in}x{n_out}");

        // bit-exact parity before any timing, at every thread count
        for &threads in &threads_grid {
            assert_engine_matches_oracle(&base, &cfg, threads, &format!("{shape} t{threads}"));
        }

        // sequential oracle: evolve the same model repeatedly — nnz is
        // stationary under SET, so every iteration is a steady-state epoch
        let mut om = base.clone();
        let mut orng = Rng::new(2);
        let (oracle_secs, _) = time_it(1, iters, || {
            set::evolve_model(&mut om, &cfg, &mut orng).unwrap();
        });

        for &threads in &threads_grid {
            let mut m = base.clone();
            let mut engine = EvolutionEngine::new();
            let mut erng = Rng::new(2);
            let (engine_secs, _) = time_it(1, iters, || {
                engine.evolve_model(&mut m, &cfg, &mut erng, threads).unwrap();
            });
            table.row(vec![
                "evolve_epoch".into(),
                shape.clone(),
                format!("{eps}"),
                nnz.to_string(),
                threads.to_string(),
                format!("{:.3}", oracle_secs * 1e3),
                format!("{:.3}", engine_secs * 1e3),
                format!("{:.2}x", oracle_secs / engine_secs.max(1e-12)),
            ]);
            rows.push(json_row(
                "evolve_epoch",
                n_in,
                n_out,
                eps,
                nnz,
                threads,
                oracle_secs,
                engine_secs,
            ));
        }
    }

    // fused importance+SET epoch vs the two-call oracle, one deep model
    {
        let mut rng = Rng::new(3);
        let base = SparseMlp::new(
            &[3072, 4000, 4000, 1000, 10],
            20.0,
            Activation::Relu,
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        let nnz = base.weight_count();
        let imp = ImportanceConfig {
            start_epoch: 0,
            period: 1,
            percentile: 5.0,
            min_connections: 16,
        };
        // two-call oracle: prune_model + evolve_model, fresh clone per
        // iteration (the oracle path mutates nnz downward via importance)
        let (oracle_secs, _) = time_it(1, iters.min(6), || {
            let mut m = base.clone();
            importance::prune_model(&mut m, &imp);
            set::evolve_model(&mut m, &cfg, &mut Rng::new(4)).unwrap();
        });
        for &threads in &threads_grid {
            let mut engine = EvolutionEngine::new();
            let (engine_secs, _) = time_it(1, iters.min(6), || {
                let mut m = base.clone();
                engine
                    .evolve_epoch(&mut m, Some(&cfg), Some(&imp), &mut Rng::new(4), threads)
                    .unwrap();
            });
            table.row(vec![
                "evolve_epoch+importance".into(),
                "3072-4000x2-1000-10".into(),
                "20".into(),
                nnz.to_string(),
                threads.to_string(),
                format!("{:.3}", oracle_secs * 1e3),
                format!("{:.3}", engine_secs * 1e3),
                format!("{:.2}x", oracle_secs / engine_secs.max(1e-12)),
            ]);
            rows.push(json_row(
                "evolve_epoch+importance",
                3072,
                10,
                20.0,
                nnz,
                threads,
                oracle_secs,
                engine_secs,
            ));
        }
    }

    table.emit("perf_evolution.csv");

    let doc = obj(vec![
        ("bench", "perf_evolution".into()),
        ("pr", 3usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("host_threads", cores.into()),
        ("iters", iters.into()),
        ("zeta", Json::from(0.3f64)),
        (
            "acceptance",
            obj(vec![
                ("engine_min_speedup_vs_oracle", Json::from(1.5f64)),
                ("at_threads_ge", 4usize.into()),
                ("at_nnz_ge", 100_000usize.into()),
                (
                    "note",
                    "bit-exact engine/oracle parity asserted at every thread count before timing"
                        .into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_3.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_3.json: {e}"),
    }

    println!(
        "acceptance gate: `evolve_epoch` rows at nnz >= 100k, threads >= 4 — target \
         >= 1.50x vs the sequential oracle (allocation-free single-pass rebuild \
         plus layer- and row-level sharding)."
    );
}
