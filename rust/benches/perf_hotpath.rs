//! §Perf — hot-path micro-benchmarks (the profiling instrument for the
//! performance pass; before/after numbers recorded in EXPERIMENTS.md).
//!
//! Measures, across layer shapes and ε values:
//!   * spmm_forward / spmm_grad_input / spmm_grad_weights (L3 kernels)
//!   * spmm_backward_fused (one-pass dx+dw; DESIGN.md §5) and bias_grad
//!   * full train_step (fwd + loss + bwd + update)
//!   * SET evolution step and Erdős–Rényi init
//!   * masked-dense XLA train step (L2 path) when artifacts exist
//!
//! Reports achieved GFLOP/s (2·nnz·batch per spmm) against a naive
//! single-core roofline so optimisation progress is quantified.

use tsnn::bench::{env_usize, time_it, Table};
use tsnn::nn::MomentumSgd;
use tsnn::prelude::*;
use tsnn::set::{evolve_layer, EvolutionConfig, EvolutionEngine};
use tsnn::sparse::{erdos_renyi_epsilon, ops};

fn main() {
    let batch = env_usize("TSNN_BATCH", 128);
    let iters = env_usize("TSNN_ITERS", 20);

    let mut table = Table::new(
        "§Perf — truly-sparse hot-path kernels (1 core)",
        &["kernel", "shape", "eps", "nnz", "mean ms", "GFLOP/s"],
    );

    for &(n_in, n_out, eps) in &[
        (784usize, 1000usize, 20.0f64),
        (1000, 1000, 20.0),
        (3072, 4000, 20.0),
        (4000, 1000, 20.0),
        (65536, 4096, 5.0),
    ] {
        let mut rng = Rng::new(1);
        let w = erdos_renyi_epsilon(n_in, n_out, eps, &mut rng, &WeightInit::HeUniform);
        let nnz = w.nnz();
        let x: Vec<f32> = (0..batch * n_in).map(|_| rng.normal()).collect();
        let dz: Vec<f32> = (0..batch * n_out).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f32; batch * n_out];
        let mut dx = vec![0.0f32; batch * n_in];
        let mut dw = vec![0.0f32; nnz];
        let flops = 2.0 * nnz as f64 * batch as f64;
        let shape = format!("{n_in}x{n_out}");

        let (mean, _) = time_it(2, iters, || {
            out.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_forward(&x, batch, &w, &mut out);
        });
        table.row(vec![
            "spmm_forward".into(),
            shape.clone(),
            format!("{eps}"),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", flops / mean / 1e9),
        ]);

        let (mean, _) = time_it(2, iters, || {
            ops::spmm_grad_input(&dz, batch, &w, &mut dx);
        });
        table.row(vec![
            "spmm_grad_input".into(),
            shape.clone(),
            format!("{eps}"),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", flops / mean / 1e9),
        ]);

        let (mean, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_grad_weights(&x, &dz, batch, &w, &mut dw);
        });
        table.row(vec![
            "spmm_grad_weights".into(),
            shape.clone(),
            format!("{eps}"),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", flops / mean / 1e9),
        ]);

        // fused one-pass backward (dx + dw in one CSR traversal): compare
        // its single-core roofline against grad_input + grad_weights
        let (mean, _) = time_it(2, iters, || {
            dw.iter_mut().for_each(|v| *v = 0.0);
            ops::spmm_backward_fused(&x, &dz, batch, &w, &mut dx, &mut dw, 1);
        });
        table.row(vec![
            "spmm_backward_fused (1 thread)".into(),
            shape.clone(),
            format!("{eps}"),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", 2.0 * flops / mean / 1e9),
        ]);

        let mut db = vec![0.0f32; n_out];
        let (mean, _) = time_it(2, iters, || {
            db.iter_mut().for_each(|v| *v = 0.0);
            ops::bias_grad(&dz, batch, n_out, &mut db);
        });
        table.row(vec![
            "bias_grad".into(),
            shape.clone(),
            format!("{eps}"),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", batch as f64 * n_out as f64 / mean / 1e9),
        ]);
    }

    // end-to-end train step + evolution + init
    {
        let sizes = [784usize, 1000, 1000, 1000, 10];
        let mut rng = Rng::new(2);
        let mut model = SparseMlp::new(
            &sizes,
            20.0,
            Activation::AllRelu { alpha: 0.6 },
            &WeightInit::HeUniform,
            &mut rng,
        )
        .unwrap();
        let mut ws = model.alloc_workspace(batch);
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..batch).map(|i| (i % 10) as u32).collect();
        let opt = MomentumSgd::default();
        let nnz = model.weight_count();
        let (mean, _) = time_it(2, iters, || {
            model.train_step(&x, &y, &opt, 0.01, None, &mut ws, &mut rng);
        });
        // fwd ~2·nnz·B, grad_in ~2·nnz·B, grad_w ~2·nnz·B
        let flops = 6.0 * nnz as f64 * batch as f64;
        table.row(vec![
            "train_step (fashion arch)".into(),
            "784-1000x3-10".into(),
            "20".into(),
            nnz.to_string(),
            format!("{:.3}", mean * 1e3),
            format!("{:.2}", flops / mean / 1e9),
        ]);

        let (mean, _) = time_it(1, iters.min(10), || {
            let mut l = model.layers[1].clone();
            evolve_layer(&mut l, &EvolutionConfig::default(), &mut rng).unwrap();
        });
        table.row(vec![
            "evolve_layer oracle (clone incl.)".into(),
            "1000x1000".into(),
            "20".into(),
            model.layers[1].weights.nnz().to_string(),
            format!("{:.3}", mean * 1e3),
            "-".into(),
        ]);

        // the in-place engine on the full model, workspace reused across
        // iterations (the steady-state training-loop configuration;
        // DESIGN.md §8) — sequential budget so the row stays a
        // single-core roofline like the rest of this bench
        let mut evolver = EvolutionEngine::new();
        let (mean, _) = time_it(1, iters.min(10), || {
            evolver
                .evolve_model(&mut model, &EvolutionConfig::default(), &mut rng, 1)
                .unwrap();
        });
        table.row(vec![
            "evolution engine (in-place, t=1)".into(),
            "784-1000x3-10".into(),
            "20".into(),
            model.weight_count().to_string(),
            format!("{:.3}", mean * 1e3),
            "-".into(),
        ]);

        let (mean, _) = time_it(1, iters.min(10), || {
            erdos_renyi_epsilon(3072, 4000, 20.0, &mut rng, &WeightInit::HeUniform)
        });
        table.row(vec![
            "erdos_renyi init".into(),
            "3072x4000".into(),
            "20".into(),
            "-".into(),
            format!("{:.3}", mean * 1e3),
            "-".into(),
        ]);
    }

    // masked-dense XLA step for comparison (L2 path)
    if let Ok(m) = tsnn::runtime::Manifest::load(&tsnn::runtime::default_artifacts_dir()) {
        if let Some(arch) = m.get("fashion") {
            let mut rng = Rng::new(3);
            if let Ok(mut trainer) = tsnn::runtime::MaskedDenseTrainer::new(arch, 20.0, &mut rng)
            {
                let x: Vec<f32> = (0..arch.batch * 784).map(|_| rng.normal()).collect();
                let y: Vec<i32> = (0..arch.batch).map(|i| (i % 10) as i32).collect();
                let (mean, _) = time_it(1, iters.min(10), || {
                    trainer.step(&x, &y, 0.01).unwrap();
                });
                let dense: usize = arch
                    .sizes
                    .windows(2)
                    .map(|w| w[0] * w[1])
                    .sum();
                table.row(vec![
                    "masked-dense XLA train step".into(),
                    "784-1000x3-10".into(),
                    "dense+mask".into(),
                    dense.to_string(),
                    format!("{:.3}", mean * 1e3),
                    format!("{:.2}", 6.0 * dense as f64 * arch.batch as f64 / mean / 1e9),
                ]);
            }
        }
    }

    table.emit("perf_hotpath.csv");
}
