//! Table 2 — sequential SET-MLP evaluation.
//!
//! For each of the five datasets: SET-MLP with {ReLU, All-ReLU} ×
//! {Importance Pruning off/on}, plus the masked-dense XLA baseline row
//! (the paper's "Dense/Keras MLP" comparator; run for a few epochs and
//! reported per-epoch). Prints accuracy, start/end weight counts and
//! training time — the paper's exact row format — and emits the Fig. 4
//! (relative size vs relative error) and Fig. 6/7 (learning curve) CSVs.
//!
//! Env: TSNN_SCALE=paper for Table-1 shapes & 500 epochs,
//!      TSNN_EPOCHS / TSNN_TRIALS overrides, TSNN_DATASETS=a,b,c subset.

use tsnn::bench::{env_usize, fmt_duration, paper_scale, write_artifact, Table};
use tsnn::config::{DatasetSpec, TrainConfig};
use tsnn::importance::ImportanceConfig;
use tsnn::nn::Activation;
use tsnn::prelude::*;
use tsnn::train::train_sequential;

fn main() {
    let paper = paper_scale();
    let epochs = env_usize("TSNN_EPOCHS", if paper { 500 } else { 6 });
    let trials = env_usize("TSNN_TRIALS", if paper { 5 } else { 1 });
    let datasets_env = std::env::var("TSNN_DATASETS")
        .unwrap_or_else(|_| "leukemia,higgs,madelon,fashion,cifar".into());
    let datasets: Vec<&str> = datasets_env.split(',').collect();

    let mut table = Table::new(
        "Table 2 — sequential SET-MLP (truly sparse, 1 core)",
        &["dataset", "activation", "imp. pruning", "acc [%]", "start_w", "end_w", "train"],
    );
    let mut fig4 = String::from("dataset,variant,rel_size,rel_test_error,rel_train_error\n");

    for name in &datasets {
        let spec = if paper {
            DatasetSpec::paper(name)
        } else {
            DatasetSpec::small(name)
        };
        let data = match tsnn::data::generate(&spec, &mut Rng::new(1)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };

        let cell = |act: Activation, pruning: bool| -> (f32, usize, usize, f64, f32) {
            let mut best = 0.0f32;
            let mut train_err = 0.0f32;
            let (mut sw, mut ew, mut secs) = (0, 0, 0.0);
            for trial in 0..trials {
                let mut cfg = if paper {
                    TrainConfig::paper_preset(name)
                } else {
                    TrainConfig::small_preset(name)
                };
                cfg.epochs = epochs;
                cfg.activation = match (act, cfg.activation) {
                    (Activation::Relu, _) => Activation::Relu,
                    (_, Activation::AllRelu { alpha }) => Activation::AllRelu { alpha },
                    (a, _) => a,
                };
                cfg.importance = pruning.then(|| ImportanceConfig {
                    start_epoch: (epochs * 2 / 5).max(1),
                    period: (epochs / 10).max(1),
                    percentile: 5.0,
                    min_connections: 64,
                });
                cfg.seed = 42 + trial as u64;
                let report =
                    train_sequential(&cfg, &data, &mut Rng::new(cfg.seed)).expect("train");
                if report.best_test_accuracy > best {
                    best = report.best_test_accuracy;
                    // emit curves for the best trial of the All-ReLU runs
                    let variant = format!(
                        "{}_{}{}",
                        name,
                        if matches!(act, Activation::Relu) { "relu" } else { "allrelu" },
                        if pruning { "_pruned" } else { "" }
                    );
                    let _ = write_artifact(&format!("fig6_7_curve_{variant}.csv"),
                                           &report.curves_csv());
                }
                train_err = report
                    .epochs
                    .last()
                    .map(|e| 1.0 - e.train_accuracy)
                    .unwrap_or(1.0);
                sw = report.start_weights;
                ew = report.end_weights;
                secs += report.phases.get("train");
            }
            (best, sw, ew, secs / trials as f64, train_err)
        };

        let mut base_size = 0usize;
        let mut base_err = (0.0f32, 0.0f32);
        for (act, act_label) in [
            (Activation::Relu, "ReLU"),
            (Activation::AllRelu { alpha: 0.6 }, "All-ReLU"),
        ] {
            for pruning in [false, true] {
                let (acc, sw, ew, secs, terr) = cell(act, pruning);
                table.row(vec![
                    name.to_string(),
                    act_label.into(),
                    if pruning { "yes" } else { "no" }.into(),
                    format!("{:.2}", acc * 100.0),
                    sw.to_string(),
                    ew.to_string(),
                    fmt_duration(secs),
                ]);
                // Fig. 4 relative points (vs the unpruned run of same act)
                if !pruning {
                    base_size = ew;
                    base_err = (1.0 - acc, terr);
                } else if base_size > 0 {
                    fig4.push_str(&format!(
                        "{name},{act_label},{:.4},{:.4},{:.4}\n",
                        ew as f64 / base_size as f64,
                        (1.0 - acc) / base_err.0.max(1e-6),
                        terr / base_err.1.max(1e-6)
                    ));
                }
            }
        }
    }

    table.emit("table2_sequential.csv");
    let _ = write_artifact("fig4_relative.csv", &fig4);
    println!("paper reference (Table 2): All-ReLU > ReLU on all datasets;");
    println!("Importance Pruning: up to 80% fewer end weights at ~equal accuracy.");
}
