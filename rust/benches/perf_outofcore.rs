//! §Perf — out-of-core mmap-backed training beyond a RAM budget
//! (DESIGN.md §14). Emits a machine-readable `BENCH_7.json` at the
//! repository root.
//!
//! Two legs, parity first:
//!   * `parity` — a small mapped run and a small in-RAM run from equal
//!     seeds must produce **byte-identical** checkpoints before any
//!     timing happens (same arithmetic over mapped memory, so
//!     `assert_eq!`, no tolerances).
//!   * `scale` — a recommender model whose segment files exceed the
//!     configured RAM budget is created (streaming, O(rows + chunk)
//!     resident) and trained with the residency advisor holding RSS
//!     under the budget. Acceptance, asserted in-process against
//!     `/proc/self/status` VmHWM and echoed into the JSON:
//!     `segment_bytes > budget > peak RSS`.
//!
//! The scale leg leans on the activity-gated optimizer update
//! (DESIGN.md §14.6): with `weight_decay = 0` the trainer provably
//! never needs to touch values/velocity pages of input rows that no
//! sample activates, so a wide-sparse recommender input layer stays on
//! disk. The honest floor that remains is the aligned gradient
//! workspace (RAM, nnz × 4 B ≈ 1/3 of segment bytes), the dense
//! dataset, the evaluation activation buffer (256 × features f32), and
//! the fully-active upper layers — the default shape puts ~90 % of its
//! ~44 M links in the gated input layer, leaving peak RSS around 3/4
//! of the segment total.
//!
//! Knobs: TSNN_BUDGET_MB (default 450), TSNN_FEATURES (65536),
//! TSNN_HIDDEN_WIDTH (1024), TSNN_HIDDEN_DEPTH (4), TSNN_EPSILON
//! (600), TSNN_EPOCHS (2), TSNN_TRAIN (64), TSNN_TEST (16),
//! TSNN_BATCH (32), TSNN_DIR (defaults to a temp directory, removed
//! afterwards). Requires Linux (`/proc`, mmap) and a 64-bit target.

#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
fn main() {
    eprintln!("perf_outofcore requires Linux and a 64-bit target; skipping");
}

#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
fn main() {
    use std::path::PathBuf;

    use tsnn::bench::{env_usize, host_info, write_repo_root_json, Table};
    use tsnn::bigmodel::{train_big, vm_hwm_bytes, BigModel, BigTrainOptions};
    use tsnn::config::{DatasetSpec, TrainConfig};
    use tsnn::data::datasets;
    use tsnn::model::checkpoint;
    use tsnn::train::{train_sequential_opts, TrainOptions};
    use tsnn::util::json::{obj, Json};
    use tsnn::util::{Rng, Timer};

    let budget_mb = env_usize("TSNN_BUDGET_MB", 450);
    let features = env_usize("TSNN_FEATURES", 65_536);
    let width = env_usize("TSNN_HIDDEN_WIDTH", 1_024);
    let depth = env_usize("TSNN_HIDDEN_DEPTH", 4);
    let epsilon = env_usize("TSNN_EPSILON", 600);
    let epochs = env_usize("TSNN_EPOCHS", 2);
    let n_train = env_usize("TSNN_TRAIN", 64);
    let n_test = env_usize("TSNN_TEST", 16);
    let batch = env_usize("TSNN_BATCH", 32);
    let budget_bytes = (budget_mb as u64) * 1024 * 1024;
    let dir = std::env::var("TSNN_DIR").map(PathBuf::from).unwrap_or_else(|_| {
        std::env::temp_dir().join(format!("tsnn_bench_outofcore_{}", std::process::id()))
    });
    let _ = std::fs::remove_dir_all(&dir);

    let mut rows: Vec<Json> = Vec::new();

    // ---- 1. parity: mapped vs in-RAM, byte-identical checkpoints ----
    {
        let spec = DatasetSpec {
            name: "recommender-parity".into(),
            generator: "recommender".into(),
            n_features: 256,
            n_classes: 4,
            n_train: 300,
            n_test: 100,
        };
        let mut cfg = TrainConfig::small_preset("recommender");
        for (k, v) in [
            ("epochs", "5"),
            ("batch", "32"),
            ("hidden", "48x24"),
            ("epsilon", "6"),
            ("zeta", "0.3"),
            ("importance", "on"),
            ("importance_start", "1"),
            ("importance_period", "2"),
            ("importance_min", "0"),
            ("eval_every", "2"),
            ("seed", "4711"),
        ] {
            cfg.set(k, v).unwrap();
        }
        let mut rng = Rng::new(cfg.seed);
        let data = datasets::generate(&spec, &mut rng).unwrap();
        let report =
            train_sequential_opts(&cfg, &data, &mut rng, TrainOptions::default()).unwrap();

        let pdir = dir.join("parity");
        std::fs::create_dir_all(&pdir).unwrap();
        let mut rng2 = Rng::new(cfg.seed);
        let data2 = datasets::generate(&spec, &mut rng2).unwrap();
        let sizes = cfg.sizes(data2.n_features, data2.n_classes);
        let mut big =
            BigModel::create(&pdir, &sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut rng2)
                .unwrap();
        train_big(&cfg, &data2, &mut big, &mut rng2, &BigTrainOptions::default()).unwrap();

        let p_ram = pdir.join("ram.tsnn");
        let p_map = pdir.join("mapped.tsnn");
        checkpoint::save(&report.model, &p_ram).unwrap();
        big.save_checkpoint(&p_map).unwrap();
        let (ram, mapped) = (std::fs::read(&p_ram).unwrap(), std::fs::read(&p_map).unwrap());
        assert_eq!(ram, mapped, "mapped vs in-RAM checkpoints must be byte-identical");
        println!("parity: mapped == in-RAM, {} checkpoint bytes", ram.len());
        rows.push(obj(vec![
            ("op", "parity".into()),
            ("checkpoint_bytes", ram.len().into()),
            ("equal", true.into()),
        ]));
    }

    // ---- 2. scale: segments beyond the budget, RSS under it ----
    let hidden: Vec<usize> = vec![width; depth];
    let spec = DatasetSpec {
        name: "recommender-extreme".into(),
        generator: "recommender".into(),
        n_features: features,
        n_classes: 16,
        n_train,
        n_test,
    };
    let mut cfg = TrainConfig::small_preset("recommender");
    cfg.hidden = hidden;
    for (k, v) in [
        ("epsilon", epsilon.to_string()),
        ("epochs", epochs.to_string()),
        ("batch", batch.to_string()),
        // weight_decay = 0 arms the activity-gated update (§14.6) — with
        // decay every weight moves every step and nothing can stay on disk
        ("weight_decay", "0".into()),
        ("evolution", "off".into()),
        ("eval_every", "1".into()),
        ("seed", "77".into()),
        ("kernel_threads", "0".into()),
    ] {
        cfg.set(k, &v).unwrap();
    }

    let mut rng = Rng::new(cfg.seed);
    let data = datasets::generate(&spec, &mut rng).unwrap();
    let dataset_bytes = (data.x_train.len() + data.x_test.len()) * 4;
    let sizes = cfg.sizes(data.n_features, data.n_classes);

    let sdir = dir.join("scale");
    let timer = Timer::start();
    let mut big =
        BigModel::create(&sdir, &sizes, cfg.epsilon, cfg.activation, &cfg.init, &mut rng)
            .unwrap();
    let create_secs = timer.secs();
    let segment_bytes = big.total_segment_bytes();
    let nnz = big.mlp.weight_count();
    println!(
        "created {} layers, {} links, {:.1} MiB of segments in {create_secs:.1}s \
         (budget {budget_mb} MiB, dataset {:.1} MiB)",
        sizes.len() - 1,
        nnz,
        segment_bytes as f64 / (1024.0 * 1024.0),
        dataset_bytes as f64 / (1024.0 * 1024.0),
    );
    rows.push(obj(vec![
        ("op", "create".into()),
        ("nnz", nnz.into()),
        ("segment_bytes", (segment_bytes as f64).into()),
        ("secs", create_secs.into()),
    ]));

    let opts = BigTrainOptions {
        soft_budget_bytes: Some(budget_bytes),
        residency_check_every: 4,
        persist_every: 0,
        verbose: false,
    };
    let timer = Timer::start();
    let report = train_big(&cfg, &data, &mut big, &mut rng, &opts).unwrap();
    let train_secs = timer.secs();
    let end_segment_bytes = big.total_segment_bytes();
    let mut table = Table::new(
        "§Perf — out-of-core training epochs (mapped segments, residency advisor)",
        &["epoch", "train loss", "train acc", "test acc", "weights", "secs"],
    );
    for e in &report.epochs {
        table.row(vec![
            e.epoch.to_string(),
            format!("{:.4}", e.train_loss),
            format!("{:.4}", e.train_accuracy),
            format!("{:.4}", e.test_accuracy),
            e.weight_count.to_string(),
            format!("{:.1}", e.seconds),
        ]);
        rows.push(obj(vec![
            ("op", "epoch".into()),
            ("epoch", e.epoch.into()),
            ("train_loss", (e.train_loss as f64).into()),
            ("test_accuracy", (e.test_accuracy as f64).into()),
            ("weights", e.weight_count.into()),
            ("secs", e.seconds.into()),
        ]));
    }
    println!("{}", table.to_markdown());
    table.emit("perf_outofcore_epochs.csv");

    // ---- 3. acceptance: disk > budget > peak RSS ----
    let peak = report.peak_rss_bytes.or_else(vm_hwm_bytes).expect("VmHWM on Linux");
    println!(
        "residency: segments {:.1} MiB (end {:.1}), peak RSS {:.1} MiB, budget {budget_mb} MiB, \
         {} trims, trained in {train_secs:.1}s",
        segment_bytes as f64 / (1024.0 * 1024.0),
        end_segment_bytes as f64 / (1024.0 * 1024.0),
        peak as f64 / (1024.0 * 1024.0),
        report.trim_events,
    );
    rows.push(obj(vec![
        ("op", "residency".into()),
        ("segment_bytes", (segment_bytes.max(end_segment_bytes) as f64).into()),
        ("budget_bytes", (budget_bytes as f64).into()),
        ("peak_rss_bytes", (peak as f64).into()),
        ("trim_events", report.trim_events.into()),
        ("dataset_bytes", dataset_bytes.into()),
        (
            "disk_over_budget",
            (segment_bytes.max(end_segment_bytes) as f64 / budget_bytes as f64).into(),
        ),
    ]));

    let doc = obj(vec![
        ("bench", "perf_outofcore".into()),
        ("pr", 10usize.into()),
        ("status", "measured".into()),
        ("host", host_info()),
        ("budget_mb", budget_mb.into()),
        ("features", features.into()),
        ("hidden_width", width.into()),
        ("hidden_depth", depth.into()),
        ("epsilon", epsilon.into()),
        ("epochs", epochs.into()),
        (
            "acceptance",
            obj(vec![
                ("require_segments_exceed_budget", true.into()),
                ("require_peak_rss_under_budget", true.into()),
                (
                    "note",
                    "the residency row must show segment_bytes > budget_bytes (the model \
                     genuinely does not fit the budget) and peak_rss_bytes < budget_bytes \
                     (VmHWM from /proc/self/status, i.e. the whole process' high-water mark \
                     including dataset and gradient workspace); the gap is opened by the \
                     activity-gated optimizer update (weight_decay=0, DESIGN.md 14.6) which \
                     leaves inactive input rows untouched on disk; mapped-vs-RAM parity is \
                     asserted byte-exact before any timing"
                        .into(),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    match write_repo_root_json("BENCH_7.json", &doc) {
        Ok(path) => println!("(json written to {})", path.display()),
        Err(e) => eprintln!("warn: could not write BENCH_7.json: {e}"),
    }

    assert!(
        segment_bytes.max(end_segment_bytes) > budget_bytes,
        "segments ({segment_bytes} B) must exceed the RAM budget ({budget_bytes} B) — \
         raise TSNN_EPSILON/TSNN_HIDDEN_DEPTH or lower TSNN_BUDGET_MB"
    );
    assert!(
        peak < budget_bytes,
        "peak RSS ({peak} B) breached the budget ({budget_bytes} B) with {} trims — \
         the residency advisor failed to hold the ceiling",
        report.trim_events
    );
    println!(
        "acceptance gate: disk {:.1} MiB > budget {budget_mb} MiB > peak RSS {:.1} MiB — ok",
        segment_bytes.max(end_segment_bytes) as f64 / (1024.0 * 1024.0),
        peak as f64 / (1024.0 * 1024.0),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
