//! Minimal offline stand-in for the `log` crate facade.
//!
//! The build environment has no network access to crates.io, so this
//! vendored crate provides the subset of `log` 0.4's API that `tsnn`
//! uses (see `rust/DESIGN.md` §3 Substitutions): the five level macros,
//! the [`Log`] trait, a global logger slot, and the max-level filter.
//! API signatures mirror the real crate so swapping in upstream `log`
//! is a one-line `Cargo.toml` change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging severity, most severe first (matches `log::Level`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// Unrecoverable errors.
    Error = 1,
    /// Recoverable problems worth surfacing.
    Warn,
    /// High-level progress (default).
    Info,
    /// Developer diagnostics.
    Debug,
    /// Very verbose tracing.
    Trace,
}

/// Level filter for the global maximum (matches `log::LevelFilter`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// Disable all logging.
    Off = 0,
    /// `Error` only.
    Error,
    /// `Warn` and above.
    Warn,
    /// `Info` and above.
    Info,
    /// `Debug` and above.
    Debug,
    /// Everything.
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level only in this stand-in).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    /// The record's severity.
    pub fn level(&self) -> Level {
        self.level
    }
}

/// A single log record: metadata plus the formatted message arguments.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// The record's severity.
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    /// The record's metadata.
    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    /// The message, ready to be passed to a formatting macro.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A sink for log records (matches `log::Log`).
pub trait Log: Send + Sync {
    /// Whether a record with this metadata would be logged.
    fn enabled(&self, metadata: &Metadata) -> bool;
    /// Consume a record.
    fn log(&self, record: &Record);
    /// Flush buffered output.
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger; fails if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, if any.
pub fn logger() -> Option<&'static dyn Log> {
    LOGGER.get().copied()
}

/// Set the global maximum level; records above it are skipped.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro back-end: filter by max level, then hand to the logger.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments) {
    if level <= max_level() {
        if let Some(l) = logger() {
            let record = Record {
                metadata: Metadata { level },
                args,
            };
            if l.enabled(record.metadata()) {
                l.log(&record);
            }
        }
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, format_args!($($arg)+))
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    static SEEN: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            let _ = format!("{}", record.args());
            SEEN.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_against_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    #[test]
    fn logger_filters_and_counts() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = SEEN.load(Ordering::Relaxed);
        crate::info!("hello {}", 1);
        crate::debug!("filtered out");
        let after = SEEN.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
        assert!(logger().is_some());
    }
}
