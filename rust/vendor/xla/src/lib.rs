//! Offline stub of the `xla` (PJRT) binding surface used by `tsnn`.
//!
//! The real `xla` crate links libxla/PJRT, which cannot be built in the
//! offline container (see `rust/DESIGN.md` §3 Substitutions). This stub
//! keeps the API shape so `tsnn::runtime` compiles unchanged:
//!
//! * [`Literal`] is a real in-memory tensor container — building,
//!   reshaping and reading literals works, so shape plumbing stays
//!   testable without a backend;
//! * everything that would execute XLA ([`PjRtClient::cpu`],
//!   `compile`, `execute`) returns an "unavailable in offline build"
//!   [`Error`], which callers already handle (the masked-dense baseline
//!   is optional and skipped when artifacts/backends are missing).
//!
//! Swapping the real crate back in is a one-line `Cargo.toml` change.

use std::fmt;
use std::path::Path;

/// XLA/PJRT error (stub: carries a message only).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: XLA/PJRT backend unavailable in the offline build \
         (stub crate; see rust/DESIGN.md §3 Substitutions)"
    )))
}

/// Element storage for [`Literal`] (implementation detail).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    /// f32 elements.
    F32(Vec<f32>),
    /// i32 elements.
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn into_data(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// In-memory tensor literal (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::into_data(data.to_vec()),
        }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::F32(vec![v]),
        }
    }

    /// Reshape to `dims`; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let expect: i64 = dims.iter().product();
        if expect < 0 || expect as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} wants {expect} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Shape of this literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element count.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Copy elements out; errors on element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_data(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    /// First element; errors on empty literal or type mismatch.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".to_string()))
    }

    /// Decompose a tuple literal — tuples only come out of `execute`,
    /// which the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU PJRT client — unavailable offline.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation — unreachable in the stub (no client can
    /// exist), kept for API parity.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file — unavailable offline.
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto, Error> {
        unavailable(&format!("HloModuleProto::from_text_file({})", path.display()))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub: never produced).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy device buffer to a host literal — unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Loaded executable handle (stub: never produced).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given inputs — unreachable in the stub.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_build_reshape_read() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7.0).get_first_element::<f32>().unwrap(), 7.0);
        let i = Literal::vec1(&[1i32, 2]);
        assert!(i.to_vec::<f32>().is_err());
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn backend_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope.hlo")).is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("offline"));
    }
}
